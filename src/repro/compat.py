"""jax version-compatibility layer.

The repo targets both jax 0.4.x (the pinned toolchain on this machine) and
newer releases whose public API moved under different names:

  * ``jax.shard_map``           — 0.4.x only has ``jax.experimental.shard_map``
                                  whose replication-check kwarg is ``check_rep``
                                  (renamed ``check_vma`` upstream);
  * ``jax.sharding.AxisType``   — absent on 0.4.x (meshes are implicitly Auto);
  * ``jax.make_mesh(axis_types=...)`` — the kwarg does not exist on 0.4.x;
  * ``jax.tree.*``              — present on 0.4.x but kept behind one alias so
                                  very old/new trees of utilities stay swappable.

Everything that builds meshes or shard_map programs (core engine, iterative
driver, MoE dispatch, checkpointing, sharding rules, tests, examples,
benchmarks) imports from here instead of touching the moving jax surface
directly.
"""

from __future__ import annotations

import enum
import inspect
import math
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax.sharding import Mesh

# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

if hasattr(jax, "tree"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
    tree_structure = jax.tree.structure
else:  # pragma: no cover - ancient jax
    from jax import tree_util as _tu

    tree_map = _tu.tree_map
    tree_leaves = _tu.tree_leaves
    tree_flatten = _tu.tree_flatten
    tree_unflatten = _tu.tree_unflatten
    tree_structure = _tu.tree_structure


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:
    AxisType = jax.sharding.AxisType  # jax >= 0.5-ish
except AttributeError:

    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on jax 0.4.x.

        0.4.x meshes behave as all-Auto, so the value is accepted (and
        dropped) by :func:`make_mesh` purely for source compatibility.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

_MAKE_MESH_PARAMS: frozenset[str] = (
    frozenset(inspect.signature(jax.make_mesh).parameters)
    if hasattr(jax, "make_mesh")
    else frozenset()
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
    axis_types=None,
) -> Mesh:
    """Version-safe ``jax.make_mesh``: ``axis_types`` is forwarded when the
    running jax understands it and silently dropped otherwise (0.4.x meshes
    are implicitly Auto, which is what every caller here wants)."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if hasattr(jax, "make_mesh"):
        kwargs: dict[str, Any] = {}
        if devices is not None:
            kwargs["devices"] = devices
        if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
            kwargs["axis_types"] = axis_types
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    # pragma: no cover - pre-make_mesh jax
    n = math.prod(axis_shapes)
    devices = list(devices) if devices is not None else jax.devices()[:n]
    return Mesh(np.array(devices).reshape(axis_shapes), axis_names)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _CHECK_KW = (
        "check_vma"
        if "check_vma" in inspect.signature(jax.shard_map).parameters
        else "check_rep"
    )
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f: Callable, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-safe shard_map.

    ``check_vma`` maps onto the running jax's replication-check kwarg
    (``check_vma`` on new jax, ``check_rep`` on 0.4.x experimental).
    """
    return _shard_map_impl(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )

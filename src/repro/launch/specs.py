"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation: params/opt-state/batch/cache are all abstract, with
NamedShardings attached so `.lower()` sees the production placement.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.lm import init_params, param_axes
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import logical_to_spec, rules_for_mesh
from repro.serve.engine import cache_specs, init_cache


def _sharded_sds(tree, spec_tree, mesh):
    def mk(x, spec):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(mk, tree, spec_tree)


def abstract_params(cfg: ArchConfig, mesh: Mesh):
    n_model = mesh.shape.get("model", 1)
    shapes = jax.eval_shape(partial(init_params, cfg, n_model=n_model), jax.random.key(0))
    specs = logical_to_spec(param_axes(cfg), rules_for_mesh(mesh, cfg))
    return _sharded_sds(shapes, specs, mesh)


def abstract_opt_state(cfg: ArchConfig, mesh: Mesh, params_sds):
    shapes = jax.eval_shape(adamw_init, params_sds)
    p_specs = logical_to_spec(param_axes(cfg), rules_for_mesh(mesh, cfg))
    specs = {"mu": p_specs, "nu": p_specs, "count": P()}
    return _sharded_sds(shapes, specs, mesh)


def _dp(mesh, batch: int | None = None):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    if batch is not None and dp is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if batch % dp_size != 0:
            return None  # e.g. long-context batch=1: replicate
    return dp


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """Abstract inputs for the cell's entry point.

    train  -> (params, opt_state, batch, step)
    prefill-> (params, tokens, cache)
    decode -> (params, cache, tokens)
    """
    b = shape.global_batch
    dp = _dp(mesh, b)
    params = abstract_params(cfg, mesh)

    def tok_sds(t):
        return jax.ShapeDtypeStruct((b, t), jnp.int32, sharding=NamedSharding(mesh, P(dp, None)))

    if shape.kind == "train":
        opt = abstract_opt_state(cfg, mesh, params)
        batch = {"tokens": tok_sds(shape.seq_len)}
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, P(dp, None, None)),
            )
        step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
        return {"params": params, "opt_state": opt, "batch": batch, "step": step}

    if cfg.serve_bf16_params:
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype,
                sharding=s.sharding,
            ),
            params,
        )

    cache_shapes = jax.eval_shape(
        partial(init_cache, cfg, b, shape.seq_len, mesh=None)
    )
    c_specs = cache_specs(cfg, mesh, batch=b)
    cache = _sharded_sds(cache_shapes, c_specs, mesh)

    if shape.kind == "prefill":
        spec = {"params": params, "tokens": tok_sds(shape.seq_len), "cache": cache}
        if cfg.family == "audio":
            spec["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, P(dp, None, None)),
            )
        return spec

    # decode: one new token against a full-length cache
    return {"params": params, "cache": cache, "tokens": tok_sds(1)}

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:  jit(entry).lower(**input_specs) -> compile ->
memory_analysis + cost_analysis + collective-bytes parse (tools/hlo.py).
Results cached incrementally in reports/dryrun.json so reruns only do
missing cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod  # 2x16x16
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape, shape_skips
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.tools.hlo import collective_bytes, roofline_terms

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun.json")


def pick_accum(cfg, shape, mesh) -> int:
    """Gradient-accumulation factor: keep tokens/chip/microbatch ~<=8k, with
    an extra factor for >80B-param archs; bounded by batch/dp divisibility."""
    from repro.tools.roofline import param_counts

    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    max_accum = max(1, shape.global_batch // dp)
    total, _ = param_counts(cfg)
    want = 16 if total > 80e9 else 8
    return min(want, max_accum)


def entry_fn(cfg, shape, mesh, accum_steps: int = 8):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.serve.engine import cache_specs

    def dp(b):
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
        if axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if b % n != 0:
                return None
        return axes

    if shape.kind == "train":
        from repro.train.step import make_train_step

        sec_moe = None
        if cfg.secure_moe and cfg.family == "moe":
            from repro.core.shuffle import SecureShuffleConfig
            from repro.crypto import chacha

            sec_moe = SecureShuffleConfig(
                key_words=chacha.key_to_words(b"\x42" * 32),
                nonce_words=chacha.nonce_to_words(b"\x0a" * 12),
            )
        step, _, _ = make_train_step(
            cfg, mesh, donate=True, accum_steps=pick_accum(cfg, shape, mesh),
            secure_moe=sec_moe,
        )
        return step, ("params", "opt_state", "batch", "step")

    c_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cache_specs(cfg, mesh, batch=shape.global_batch)
    )
    if shape.kind == "prefill":
        from repro.serve.engine import prefill

        def pf(params, tokens, cache, frames=None):
            return prefill(cfg, params, tokens, cache, mesh=mesh, frames=frames)

        logits_sh = NamedSharding(mesh, P(dp(shape.global_batch), "model"))
        return (
            jax.jit(pf, donate_argnums=(2,), out_shardings=(logits_sh, c_sh)),
            ("params", "tokens", "cache") + (("frames",) if cfg.family == "audio" else ()),
        )
    from repro.serve.engine import decode_step

    def dec(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, mesh=mesh)

    logits_sh = NamedSharding(mesh, P(dp(shape.global_batch), "model"))
    return (
        jax.jit(dec, donate_argnums=(1,), out_shardings=(logits_sh, c_sh)),
        ("params", "cache", "tokens"),
    )


def run_cell(arch: str, shape_name: str, mesh_name: str, save_hlo: str | None = None,
             cfg_override: dict | None = None):
    import dataclasses

    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = get_shape(shape_name)
    skips = shape_skips(cfg)
    if shape_name in skips:
        return {"status": "SKIP", "reason": skips[shape_name]}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
    t0 = time.time()
    fn, arg_order = entry_fn(cfg, shape, mesh)
    spec = input_specs(cfg, shape, mesh)
    args = [spec[k] for k in arg_order if k in spec]
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
        mem_d["peak_per_device"] = (
            mem_d["argument_bytes"] + mem_d["output_bytes"] + mem_d["temp_bytes"]
            - mem_d["alias_bytes"]
        )
    except Exception as e:  # CPU backend caveats
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_chips = mesh.devices.size
    terms = roofline_terms(cost, coll, n_chips)

    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    return {
        "status": "OK",
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "memory": mem_d,
        "collectives": coll,
        "roofline": terms,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--report", default=REPORT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--bucket-growth", default=None,
                    help="serving size-bucket growth factor (a number > 1); "
                         "exported as $REPRO_BUCKET_GROWTH so every serving "
                         "path this run touches inherits it")
    ap.add_argument("--max-resident-runners", default=None,
                    help="serving runner-cache residency cap (int >= 1, or "
                         "'none' for unbounded); exported as "
                         "$REPRO_SERVICE_MAX_RUNNERS")
    args = ap.parse_args()

    # validate through the serving resolvers AFTER exporting, so a bad value
    # fails fast with the error that names the env var (the same contract as
    # $REPRO_CHACHA_IMPL via resolve_chacha_impl) rather than deep inside a
    # service constructed much later
    from repro.serve.service import (
        BUCKET_GROWTH_ENV, MAX_RUNNERS_ENV,
        resolve_bucket_growth, resolve_max_resident,
    )
    if args.bucket_growth is not None:
        os.environ[BUCKET_GROWTH_ENV] = str(args.bucket_growth)
        resolve_bucket_growth("auto")
    if args.max_resident_runners is not None:
        os.environ[MAX_RUNNERS_ENV] = str(args.max_resident_runners)
        resolve_max_resident("auto")

    os.makedirs(os.path.dirname(os.path.abspath(args.report)), exist_ok=True)
    results = {}
    if os.path.exists(args.report):
        with open(args.report) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]

    n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}|{shape_name}|{mesh_name}"
                if key in results and results[key].get("status") in ("OK", "SKIP") and not args.force:
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                print(f"[run]    {key} ...", flush=True)
                try:
                    r = run_cell(arch, shape_name, mesh_name, save_hlo=args.save_hlo)
                except Exception as e:
                    r = {"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                results[key] = r
                with open(args.report, "w") as f:
                    json.dump(results, f, indent=1)
                msg = r["status"]
                if r["status"] == "OK":
                    msg += (f"  lower={r['t_lower_s']}s compile={r['t_compile_s']}s "
                            f"dom={r['roofline'].get('dominant')}")
                elif r["status"] == "FAIL":
                    msg += "  " + r["error"][:200]
                print(f"         {key}: {msg}", flush=True)
    print(f"done; {n_fail} failures; report at {args.report}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

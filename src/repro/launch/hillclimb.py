import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Five cells (chosen per EXPERIMENTS.md §Perf):
  A  rwkv6-1.6b|train_4k        worst non-decode roofline fraction (memory)
  B  qwen2-moe-a2.7b|decode_32k most collective-bound dominant-term cell
  C  granite-moe-3b-a800m|train_4k  the paper's technique (secure shuffle)
  S  serving admission knobs    bucket growth x resident-runner cap, swept
                                through the virtual-time AdmissionSim
                                (runtime/sim.py) on burst + straggler traces
                                — no device, makespans only
  K  calibrated knob vectors    the FULL auto-knob cross product (cipher
                                impl x coalesce x halt loop x chunk growth
                                x bucket growth x residency cap), each
                                priced by a per-vector TimingModel from the
                                calibrated cost model (repro/perf/model.py)
                                and ranked by predicted AdmissionSim
                                makespan on the same traces

A/B/C variants are config overrides re-lowered via dryrun's run_cell; S
variants are ($REPRO_BUCKET_GROWTH, $REPRO_SERVICE_MAX_RUNNERS) settings
validated through the serving resolvers (errors name the env var, like
resolve_chacha_impl). Cell K needs a calibration: $REPRO_CALIBRATION if
set, else an in-process `run_calibration(quick=True)`. Results append to
reports/perf.json.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|S|K] [--mesh single_pod]
"""

import argparse
import json

from repro.launch.dryrun import run_cell

CELLS = {
    "A": {
        "arch": "rwkv6-1.6b",
        "shape": "train_4k",
        "variants": [
            ("v0_scan_wkv_paper_faithful", {"wkv_impl": "scan"}),
            ("v1_blocked_wkv", {"wkv_impl": "blocked"}),
            ("v2_blocked_no_remat", {"wkv_impl": "blocked", "remat": "none"}),
            ("v3_blocked_remat_dots", {"wkv_impl": "blocked", "remat": "dots"}),
        ],
    },
    "B": {
        "arch": "qwen2-moe-a2.7b",
        "shape": "decode_32k",
        "variants": [
            ("v0_tp_baseline", {}),
            ("v1_ep_only", {"shard_strategy": "ep_only"}),
            ("v2_ep_only_bf16_scores", {"shard_strategy": "ep_only",
                                        "softmax_dtype": "bfloat16"}),
            ("v3_bf16_serve_params", {"serve_bf16_params": True}),
        ],
    },
    "C": {
        "arch": "granite-moe-3b-a800m",
        "shape": "train_4k",
        "variants": [
            ("v0_secure_shuffle_paper_faithful", {"secure_moe": True}),
            ("v1_secure_save_shuffle_remat", {"secure_moe": True,
                                              "moe_remat": "save_shuffle"}),
            ("v2_secure_saveshuf_bf16_scores", {"secure_moe": True,
                                                "moe_remat": "save_shuffle",
                                                "softmax_dtype": "bfloat16"}),
            ("v3_plain_saveshuf_bf16", {"secure_moe": False,
                                        "moe_remat": "save_shuffle",
                                        "softmax_dtype": "bfloat16"}),
            ("v4_secure_saveshuf_no_expert_fsdp", {"secure_moe": True,
                                                   "moe_remat": "save_shuffle",
                                                   "moe_fsdp": False}),
        ],
    },
}

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "perf.json")

# Serving-knob sweep (cell S): each variant is a (bucket growth, resident
# runner cap) point, the two knobs the job service exposes via
# $REPRO_BUCKET_GROWTH / $REPRO_SERVICE_MAX_RUNNERS.
SERVICE_VARIANTS = [
    ("v0_g2_unbounded", {"bucket_growth": 2.0, "max_resident": None}),
    ("v1_g1.5_unbounded", {"bucket_growth": 1.5, "max_resident": None}),
    ("v2_g4_unbounded", {"bucket_growth": 4.0, "max_resident": None}),
    ("v3_g2_rmax8", {"bucket_growth": 2.0, "max_resident": 8}),
    ("v4_g2_rmax2", {"bucket_growth": 2.0, "max_resident": 2}),
]


def run_service_cell(bucket_growth, max_resident):
    """Sweep point for cell S: AdmissionSim makespans under the two knobs.

    Values go through the serving resolvers first, so an invalid setting
    fails with the error that names the env var (resolve_chacha_impl-style)
    instead of a bare number error deep in the sim.
    """
    from repro.runtime.sim import AdmissionSim, burst_trace, straggler_trace
    from repro.serve.service import resolve_bucket_growth, resolve_max_resident

    growth = resolve_bucket_growth(bucket_growth)
    cap = resolve_max_resident(max_resident if max_resident is None else int(max_resident))
    sim = AdmissionSim(bucket_growth=growth, max_resident=cap)
    out = {"status": "OK", "bucket_growth": growth, "max_resident": cap,
           "traces": {}}
    for name, trace in [("burst", burst_trace()), ("straggler", straggler_trace())]:
        bucketed = sim.run(trace, "bucketed")
        per_job = sim.run(trace, "compile-per-job")
        out["traces"][name] = {
            "bucketed_makespan_s": bucketed["makespan_s"],
            "per_job_makespan_s": per_job["makespan_s"],
            "compiles": bucketed["compiles"],
            "evictions": bucketed["evictions"],
            "mean_latency_s": bucketed["mean_latency_s"],
        }
    return out


# Calibrated knob-vector search (cell K): the cross product every `auto`
# resolver draws from, ranked offline by predicted makespan. Kept small on
# purpose — 2x2x2x3x3x2 = 144 vectors, each priced in milliseconds.
KNOB_SPACE = {
    "chacha_impl": ("pallas", "jnp"),
    "coalesce": (True, False),
    "loop_impl": ("while", "masked_scan"),
    "chunk_growth": (2, 3, 4),
    "bucket_growth": (1.5, 2.0, 4.0),
    "max_resident": (None, 8),
}


def rank_knob_vectors(model=None, *, top: int = 10) -> dict:
    """Cell K: rank the full auto-knob cross product by PREDICTED makespan.

    Each vector gets its own `TimingModel` (cipher impl sets crypto
    bandwidth, masked_scan doubles compile, per-leaf shuffle multiplies
    collective latency) and is replayed through AdmissionSim on the burst +
    straggler traces — pure prediction, no device work beyond the (cached
    or quick) calibration. The top vector is what the `auto` resolvers
    would jointly pick if they searched instead of scoring knobs one at a
    time; agreement between the two is a model-consistency check.
    """
    import itertools as it

    from repro.perf.model import CostModel, active_model
    from repro.runtime.sim import AdmissionSim, burst_trace, straggler_trace

    if model is None:
        model = active_model()
    if model is None:
        from repro.compat import make_mesh
        from repro.perf.calibrate import run_calibration

        # Probe on ONE device: this module forces a 512-device host platform
        # for the A/B/C lowering cells, and the per-device probe constants
        # don't depend on the mesh width.
        model = CostModel(run_calibration(make_mesh((1,), ("data",)),
                                          quick=True))

    traces = [("burst", burst_trace()), ("straggler", straggler_trace())]
    names = list(KNOB_SPACE)
    ranked = []
    for combo in it.product(*KNOB_SPACE.values()):
        vec = dict(zip(names, combo))
        timing = model.timing_model(impl=vec["chacha_impl"],
                                    loop_impl=vec["loop_impl"],
                                    coalesce=vec["coalesce"])
        sim = AdmissionSim(timing, bucket_growth=vec["bucket_growth"],
                           max_resident=vec["max_resident"],
                           chunk_growth=vec["chunk_growth"])
        total = sum(sim.run(t, "bucketed")["makespan_s"] for _, t in traces)
        ranked.append({"vector": vec, "predicted_makespan_s": total})
    ranked.sort(key=lambda r: r["predicted_makespan_s"])
    resolver_vec = {
        "chacha_impl": model.recommend("chacha_impl"),
        "coalesce": model.recommend("coalesce"),
        "loop_impl": model.recommend("halt_loop"),
        "chunk_growth": model.recommend("chunk_growth"),
        "bucket_growth": model.recommend("bucket_growth"),
        "max_resident": model.recommend("max_resident"),
    }
    return {
        "status": "OK",
        "backend": model.cal.backend,
        "n_vectors": len(ranked),
        "best": ranked[0],
        "top": ranked[:top],
        "resolver_vector": resolver_vec,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C", "S", "K"])
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(os.path.abspath(REPORT)), exist_ok=True)
    results = {}
    if os.path.exists(REPORT):
        with open(REPORT) as f:
            results = json.load(f)

    if args.cell in (None, "S"):
        for vname, knobs in SERVICE_VARIANTS:
            key = f"S|service|sim|{vname}"
            if key in results and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            try:
                r = run_service_cell(**knobs)
                r["variant"] = vname
            except Exception as e:
                r = {"status": "FAIL", "error": str(e)}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                burst = r["traces"]["burst"]
                print(f"   burst bucketed={burst['bucketed_makespan_s']:.0f}s "
                      f"per-job={burst['per_job_makespan_s']:.0f}s "
                      f"compiles={burst['compiles']} evict={burst['evictions']}")
            else:
                print(f"   FAIL {r['error'][:160]}")

    if args.cell in (None, "K"):
        key = "K|knobs|costmodel|v0_full_cross"
        if key in results and not args.force:
            print(f"[cached] {key}")
        else:
            print(f"[run] {key}", flush=True)
            try:
                r = rank_knob_vectors()
            except Exception as e:
                r = {"status": "FAIL", "error": str(e)}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                best = r["best"]
                print(f"   best={best['vector']} "
                      f"pred_makespan={best['predicted_makespan_s']:.0f}s")
                print(f"   resolver_vector={r['resolver_vector']}")
            else:
                print(f"   FAIL {r['error'][:160]}")

    for cell_id, cell in CELLS.items():
        if args.cell and cell_id != args.cell:
            continue
        for vname, override in cell["variants"]:
            key = f"{cell_id}|{cell['arch']}|{cell['shape']}|{args.mesh}|{vname}"
            if key in results and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            try:
                r = run_cell(cell["arch"], cell["shape"], args.mesh, cfg_override=override)
                r["variant"] = vname
                r["override"] = override
            except Exception as e:
                import traceback

                r = {"status": "FAIL", "error": str(e),
                     "trace": traceback.format_exc()[-1500:]}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                rf = r["roofline"]
                print(f"   c={rf['compute_s']:.3e} m={rf['memory_s']:.3e} "
                      f"x={rf['collective_s']:.3e} dom={rf['dominant']} "
                      f"peak={r['memory'].get('peak_per_device', 0)/2**30:.2f}GiB")
            else:
                print(f"   FAIL {r['error'][:160]}")


if __name__ == "__main__":
    main()

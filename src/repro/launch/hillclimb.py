import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Three cells (chosen per EXPERIMENTS.md §Perf):
  A  rwkv6-1.6b|train_4k        worst non-decode roofline fraction (memory)
  B  qwen2-moe-a2.7b|decode_32k most collective-bound dominant-term cell
  C  granite-moe-3b-a800m|train_4k  the paper's technique (secure shuffle)

Each variant is a config override; results append to reports/perf.json.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C] [--mesh single_pod]
"""

import argparse
import json

from repro.launch.dryrun import run_cell

CELLS = {
    "A": {
        "arch": "rwkv6-1.6b",
        "shape": "train_4k",
        "variants": [
            ("v0_scan_wkv_paper_faithful", {"wkv_impl": "scan"}),
            ("v1_blocked_wkv", {"wkv_impl": "blocked"}),
            ("v2_blocked_no_remat", {"wkv_impl": "blocked", "remat": "none"}),
            ("v3_blocked_remat_dots", {"wkv_impl": "blocked", "remat": "dots"}),
        ],
    },
    "B": {
        "arch": "qwen2-moe-a2.7b",
        "shape": "decode_32k",
        "variants": [
            ("v0_tp_baseline", {}),
            ("v1_ep_only", {"shard_strategy": "ep_only"}),
            ("v2_ep_only_bf16_scores", {"shard_strategy": "ep_only",
                                        "softmax_dtype": "bfloat16"}),
            ("v3_bf16_serve_params", {"serve_bf16_params": True}),
        ],
    },
    "C": {
        "arch": "granite-moe-3b-a800m",
        "shape": "train_4k",
        "variants": [
            ("v0_secure_shuffle_paper_faithful", {"secure_moe": True}),
            ("v1_secure_save_shuffle_remat", {"secure_moe": True,
                                              "moe_remat": "save_shuffle"}),
            ("v2_secure_saveshuf_bf16_scores", {"secure_moe": True,
                                                "moe_remat": "save_shuffle",
                                                "softmax_dtype": "bfloat16"}),
            ("v3_plain_saveshuf_bf16", {"secure_moe": False,
                                        "moe_remat": "save_shuffle",
                                        "softmax_dtype": "bfloat16"}),
            ("v4_secure_saveshuf_no_expert_fsdp", {"secure_moe": True,
                                                   "moe_remat": "save_shuffle",
                                                   "moe_fsdp": False}),
        ],
    },
}

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "perf.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C"])
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(REPORT):
        with open(REPORT) as f:
            results = json.load(f)

    for cell_id, cell in CELLS.items():
        if args.cell and cell_id != args.cell:
            continue
        for vname, override in cell["variants"]:
            key = f"{cell_id}|{cell['arch']}|{cell['shape']}|{args.mesh}|{vname}"
            if key in results and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            try:
                r = run_cell(cell["arch"], cell["shape"], args.mesh, cfg_override=override)
                r["variant"] = vname
                r["override"] = override
            except Exception as e:
                import traceback

                r = {"status": "FAIL", "error": str(e),
                     "trace": traceback.format_exc()[-1500:]}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                rf = r["roofline"]
                print(f"   c={rf['compute_s']:.3e} m={rf['memory_s']:.3e} "
                      f"x={rf['collective_s']:.3e} dom={rf['dominant']} "
                      f"peak={r['memory'].get('peak_per_device', 0)/2**30:.2f}GiB")
            else:
                print(f"   FAIL {r['error'][:160]}")


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Four cells (chosen per EXPERIMENTS.md §Perf):
  A  rwkv6-1.6b|train_4k        worst non-decode roofline fraction (memory)
  B  qwen2-moe-a2.7b|decode_32k most collective-bound dominant-term cell
  C  granite-moe-3b-a800m|train_4k  the paper's technique (secure shuffle)
  S  serving admission knobs    bucket growth x resident-runner cap, swept
                                through the virtual-time AdmissionSim
                                (runtime/sim.py) on burst + straggler traces
                                — no device, makespans only

A/B/C variants are config overrides re-lowered via dryrun's run_cell; S
variants are ($REPRO_BUCKET_GROWTH, $REPRO_SERVICE_MAX_RUNNERS) settings
validated through the serving resolvers (errors name the env var, like
resolve_chacha_impl). Results append to reports/perf.json.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|S] [--mesh single_pod]
"""

import argparse
import json

from repro.launch.dryrun import run_cell

CELLS = {
    "A": {
        "arch": "rwkv6-1.6b",
        "shape": "train_4k",
        "variants": [
            ("v0_scan_wkv_paper_faithful", {"wkv_impl": "scan"}),
            ("v1_blocked_wkv", {"wkv_impl": "blocked"}),
            ("v2_blocked_no_remat", {"wkv_impl": "blocked", "remat": "none"}),
            ("v3_blocked_remat_dots", {"wkv_impl": "blocked", "remat": "dots"}),
        ],
    },
    "B": {
        "arch": "qwen2-moe-a2.7b",
        "shape": "decode_32k",
        "variants": [
            ("v0_tp_baseline", {}),
            ("v1_ep_only", {"shard_strategy": "ep_only"}),
            ("v2_ep_only_bf16_scores", {"shard_strategy": "ep_only",
                                        "softmax_dtype": "bfloat16"}),
            ("v3_bf16_serve_params", {"serve_bf16_params": True}),
        ],
    },
    "C": {
        "arch": "granite-moe-3b-a800m",
        "shape": "train_4k",
        "variants": [
            ("v0_secure_shuffle_paper_faithful", {"secure_moe": True}),
            ("v1_secure_save_shuffle_remat", {"secure_moe": True,
                                              "moe_remat": "save_shuffle"}),
            ("v2_secure_saveshuf_bf16_scores", {"secure_moe": True,
                                                "moe_remat": "save_shuffle",
                                                "softmax_dtype": "bfloat16"}),
            ("v3_plain_saveshuf_bf16", {"secure_moe": False,
                                        "moe_remat": "save_shuffle",
                                        "softmax_dtype": "bfloat16"}),
            ("v4_secure_saveshuf_no_expert_fsdp", {"secure_moe": True,
                                                   "moe_remat": "save_shuffle",
                                                   "moe_fsdp": False}),
        ],
    },
}

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "perf.json")

# Serving-knob sweep (cell S): each variant is a (bucket growth, resident
# runner cap) point, the two knobs the job service exposes via
# $REPRO_BUCKET_GROWTH / $REPRO_SERVICE_MAX_RUNNERS.
SERVICE_VARIANTS = [
    ("v0_g2_unbounded", {"bucket_growth": 2.0, "max_resident": None}),
    ("v1_g1.5_unbounded", {"bucket_growth": 1.5, "max_resident": None}),
    ("v2_g4_unbounded", {"bucket_growth": 4.0, "max_resident": None}),
    ("v3_g2_rmax8", {"bucket_growth": 2.0, "max_resident": 8}),
    ("v4_g2_rmax2", {"bucket_growth": 2.0, "max_resident": 2}),
]


def run_service_cell(bucket_growth, max_resident):
    """Sweep point for cell S: AdmissionSim makespans under the two knobs.

    Values go through the serving resolvers first, so an invalid setting
    fails with the error that names the env var (resolve_chacha_impl-style)
    instead of a bare number error deep in the sim.
    """
    from repro.runtime.sim import AdmissionSim, burst_trace, straggler_trace
    from repro.serve.service import resolve_bucket_growth, resolve_max_resident

    growth = resolve_bucket_growth(bucket_growth)
    cap = resolve_max_resident(max_resident if max_resident is None else int(max_resident))
    sim = AdmissionSim(bucket_growth=growth, max_resident=cap)
    out = {"status": "OK", "bucket_growth": growth, "max_resident": cap,
           "traces": {}}
    for name, trace in [("burst", burst_trace()), ("straggler", straggler_trace())]:
        bucketed = sim.run(trace, "bucketed")
        per_job = sim.run(trace, "compile-per-job")
        out["traces"][name] = {
            "bucketed_makespan_s": bucketed["makespan_s"],
            "per_job_makespan_s": per_job["makespan_s"],
            "compiles": bucketed["compiles"],
            "evictions": bucketed["evictions"],
            "mean_latency_s": bucketed["mean_latency_s"],
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C", "S"])
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(os.path.abspath(REPORT)), exist_ok=True)
    results = {}
    if os.path.exists(REPORT):
        with open(REPORT) as f:
            results = json.load(f)

    if args.cell in (None, "S"):
        for vname, knobs in SERVICE_VARIANTS:
            key = f"S|service|sim|{vname}"
            if key in results and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            try:
                r = run_service_cell(**knobs)
                r["variant"] = vname
            except Exception as e:
                r = {"status": "FAIL", "error": str(e)}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                burst = r["traces"]["burst"]
                print(f"   burst bucketed={burst['bucketed_makespan_s']:.0f}s "
                      f"per-job={burst['per_job_makespan_s']:.0f}s "
                      f"compiles={burst['compiles']} evict={burst['evictions']}")
            else:
                print(f"   FAIL {r['error'][:160]}")

    for cell_id, cell in CELLS.items():
        if args.cell and cell_id != args.cell:
            continue
        for vname, override in cell["variants"]:
            key = f"{cell_id}|{cell['arch']}|{cell['shape']}|{args.mesh}|{vname}"
            if key in results and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}", flush=True)
            try:
                r = run_cell(cell["arch"], cell["shape"], args.mesh, cfg_override=override)
                r["variant"] = vname
                r["override"] = override
            except Exception as e:
                import traceback

                r = {"status": "FAIL", "error": str(e),
                     "trace": traceback.format_exc()[-1500:]}
            results[key] = r
            with open(REPORT, "w") as f:
                json.dump(results, f, indent=1)
            if r["status"] == "OK":
                rf = r["roofline"]
                print(f"   c={rf['compute_s']:.3e} m={rf['memory_s']:.3e} "
                      f"x={rf['collective_s']:.3e} dom={rf['dominant']} "
                      f"peak={r['memory'].get('peak_per_device', 0)/2**30:.2f}GiB")
            else:
                print(f"   FAIL {r['error'][:160]}")


if __name__ == "__main__":
    main()

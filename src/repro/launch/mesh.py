"""Production mesh construction.

Single pod: (16, 16) over ('data', 'model') = 256 chips (TPU v5e pod).
Multi-pod: (2, 16, 16) over ('pod', 'data', 'model') = 512 chips; the 'pod'
axis composes with 'data' for batch/FSDP sharding and carries the cross-pod
(DCN-ish) gradient reduction.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    from repro.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return make_mesh(shape, axes, devices=devices)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    from repro.compat import make_mesh

    n = len(jax.devices())
    data = data or (n // model)
    return make_mesh((data, model), ("data", "model"),
                     devices=jax.devices()[: data * model])

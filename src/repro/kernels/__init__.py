"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

  chacha20/  CTR keystream generation + XOR — the boundary-crossing tax the
             paper pays on every enclave exit (AES-NI there, VPU ARX here).
  kmeans/    fused assign+accumulate for the paper's evaluation workload
             (map step: n×k distances on the MXU, argmin, per-center sums).

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with interpret-mode switch), ref.py (pure-jnp oracle). Tests sweep
shapes/dtypes and assert_allclose against ref.
"""

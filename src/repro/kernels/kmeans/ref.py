"""Pure-jnp oracle for the fused k-means assign+accumulate kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(points, centers, weights=None):
    """Map step of the paper's k-means: nearest center + weighted partials.

    Args:
      points:  (N, D) f32
      centers: (K, D) f32
      weights: (N,) f32 validity/sample weights (None -> ones)

    Returns:
      assign (N,) int32, sums (K, D) f32, counts (K,) f32
    """
    if weights is None:
        weights = jnp.ones((points.shape[0],), jnp.float32)
    x2 = jnp.sum(points * points, axis=1, keepdims=True)  # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]  # (1, K)
    d2 = x2 + c2 - 2.0 * points @ centers.T  # (N, K)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=jnp.float32) * weights[:, None]
    sums = onehot.T @ points  # (K, D)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    return assign, sums, counts

"""jit'd wrapper for the fused k-means kernel: padding + impl dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans.kernel import DEFAULT_TILE_N, kmeans_assign_tiles
from repro.kernels.kmeans.ref import kmeans_assign_ref


@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "interpret"))
def kmeans_assign(
    points: jax.Array,
    centers: jax.Array,
    weights: jax.Array | None = None,
    *,
    impl: str = "pallas",
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = True,
):
    """assign (N,) int32, sums (K, D) f32, counts (K,) f32.

    Padded points get weight 0: they contribute to nothing (their assignment
    entries are discarded by the caller via the original N).
    """
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if impl == "jnp":
        return kmeans_assign_ref(points, centers, weights)

    tn = min(tile_n, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % tn
    if pad:
        points = jnp.concatenate([points, jnp.zeros((pad, points.shape[1]), points.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
    assign, sums, counts = kmeans_assign_tiles(
        points, centers, weights, tile_n=tn, interpret=interpret
    )
    return assign[:n], sums, counts.reshape(-1)

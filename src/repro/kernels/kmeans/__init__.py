from repro.kernels.kmeans.ops import kmeans_assign

__all__ = ["kmeans_assign"]

"""Pallas TPU kernel: fused k-means assign + per-center accumulate.

The paper's map step computes n×k distances and assigns each observation to
its nearest center; the reduce step averages. Materializing the (N, K)
distance matrix in HBM makes the step memory-bound (and on SGX triggered the
paging cliff). The fusion keeps everything for a tile in VMEM:

  grid i over point tiles (TN, D):
      d2      = |x|² − 2·x@cᵀ + |c|²        (TN, K)   MXU matmul
      assign  = argmin d2                   (TN,)     VPU
      onehot  = assign == iota(K)           (TN, K)   VPU, never leaves VMEM
      sums   += onehotᵀ @ x                 (K, D)    MXU matmul
      counts += Σ onehot                    (1, K)

Accumulator outputs map every grid step to the same block; TPU grid order is
sequential so `+=` is well-defined (the standard Pallas reduction idiom).
Tiling: TN=512 rows; centers (K, D) stay resident. VMEM ≈ TN·D + K·D + TN·K
floats — e.g. D=64, K=256: ~0.9 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512


def _kmeans_tile_kernel(x_ref, c_ref, w_ref, assign_ref, sums_ref, counts_ref):
    i = pl.program_id(0)

    x = x_ref[...]  # (TN, D)
    c = c_ref[...]  # (K, D)
    w = w_ref[...]  # (TN, 1)

    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (TN, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TN, K) = x @ c.T  on the MXU
    d2 = x2 + c2 - 2.0 * xc

    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)  # (TN,)
    assign_ref[...] = assign

    k = c.shape[0]
    onehot = (assign[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)).astype(
        jnp.float32
    ) * w  # (TN, K), weighted

    part_sums = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (K, D) = onehot.T @ x
    part_counts = jnp.sum(onehot, axis=0, keepdims=True)  # (1, K)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += part_sums
    counts_ref[...] += part_counts


def kmeans_assign_tiles(
    points: jax.Array,
    centers: jax.Array,
    weights: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = True,
):
    """Fused assign+accumulate. N must be a multiple of tile_n (ops.py pads).

    Returns assign (N,) int32, sums (K, D) f32, counts (1, K) f32.
    """
    n, d = points.shape
    k = centers.shape[0]
    assert n % tile_n == 0, (n, tile_n)
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_kmeans_tile_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(points, centers, weights.reshape(n, 1))

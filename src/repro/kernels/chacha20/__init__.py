from repro.kernels.chacha20.ops import (
    chacha20_xor_rows,
    chacha20_xor_rows_coalesced,
    chacha20_xor_words,
    ctr_crypt_array,
)

__all__ = [
    "chacha20_xor_rows",
    "chacha20_xor_rows_coalesced",
    "chacha20_xor_words",
    "ctr_crypt_array",
]

"""Pallas TPU kernel: ChaCha20-CTR keystream generation fused with XOR.

Layout: the message is a (n_blocks, 16) u32 array — one ChaCha block per row,
little-endian word order (so word-wise XOR == byte-wise XOR of the RFC
serialization). The grid tiles rows; each program materializes its tile's
keystream entirely in VMEM registers (16 vectors of shape (B, 1)) and XORs it
with the data tile in place.

TPU mapping notes:
  * ARX only: add / xor / rotl on u32 — pure VPU lanework, MXU idle. The
    16 state words live as (B, 1) vectors so every quarter-round step is a
    full-lane vector op; the 20 rounds are unrolled (no loop-carried scalars).
  * Tile = (block_rows, 16) u32 = 64·block_rows bytes. Default 2048 rows →
    128 KiB in + 128 KiB out per tile, comfortably inside 16 MiB VMEM while
    long enough to amortize control overhead.
  * The per-row counter is derived from the grid position: counters never
    round-trip through HBM, which keeps the kernel a single-pass stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto.chacha import CONSTANT_WORDS, _QR_SCHEDULE

DEFAULT_BLOCK_ROWS = 2048


def _keystream_tile(init):
    """20 unrolled ARX rounds + feed-forward over 16 (B, 1) state vectors.

    The shared cryptographic core of both tile kernels: any change here (or
    a future TPU re-tiling) applies to the single-stream and the batched
    rows kernel alike, so their keystreams cannot diverge. Returns the
    (B, 16) keystream tile.
    """

    def rotl(v, n):
        return (v << n) | (v >> (32 - n))

    xs = list(init)
    for _ in range(10):
        for a, b, c, d in _QR_SCHEDULE:
            xa, xb, xc, xd = xs[a], xs[b], xs[c], xs[d]
            xa = xa + xb
            xd = rotl(xd ^ xa, 16)
            xc = xc + xd
            xb = rotl(xb ^ xc, 12)
            xa = xa + xb
            xd = rotl(xd ^ xa, 8)
            xc = xc + xd
            xb = rotl(xb ^ xc, 7)
            xs[a], xs[b], xs[c], xs[d] = xa, xb, xc, xd

    return jnp.concatenate([x + x0 for x, x0 in zip(xs, init)], axis=1)


def _chacha20_tile_kernel(state0_ref, x_ref, y_ref, *, block_rows: int):
    pid = pl.program_id(0)
    s0 = state0_ref[...]  # (16,) u32 template: const | key | counter0 | nonce

    # Per-row block counters for this tile.
    row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, 1), 0)
    ctr = s0[12] + jnp.uint32(block_rows) * pid.astype(jnp.uint32) + row

    init = []
    for i in range(16):
        if i == 12:
            init.append(ctr)
        else:
            init.append(jnp.broadcast_to(s0[i], (block_rows, 1)))

    y_ref[...] = x_ref[...] ^ _keystream_tile(init)


def chacha20_xor_blocks(
    x_blocks: jax.Array,
    state0: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR a (n_blocks, 16) u32 message with the keystream.

    `state0` is the 16-word template state (constants, key, counter0, nonce);
    row i uses block counter state0[12] + i. n_blocks must be a multiple of
    block_rows (ops.py pads).
    """
    n_blocks = x_blocks.shape[0]
    assert x_blocks.shape[1] == 16 and x_blocks.dtype == jnp.uint32
    assert n_blocks % block_rows == 0, (n_blocks, block_rows)
    grid = (n_blocks // block_rows,)
    return pl.pallas_call(
        functools.partial(_chacha20_tile_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((16,), lambda i: (0,)),  # template state, replicated
            pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 16), jnp.uint32),
        interpret=interpret,
    )(state0, x_blocks)


def _chacha20_rows_tile_kernel(state0_ref, nid_ref, ctr_ref, x_ref, y_ref, *,
                               block_rows: int):
    """One (row, block-tile) program of the batched multi-row stream.

    The grid is (n_rows, n_block_tiles): program (i, j) encrypts blocks
    [j*block_rows, (j+1)*block_rows) of wire row i. The row's nonce is the
    template nonce with word 0 XOR nid_ref[0]; its block counters start at
    ctr_ref[0] (absolute — state0 word 12 is ignored). The ARX core is the
    shared `_keystream_tile`.
    """
    tile = pl.program_id(1)
    s0 = state0_ref[...]  # (16,) u32 template: const | key | (ignored) | nonce
    nid = nid_ref[0]
    ctr0 = ctr_ref[0]

    row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, 1), 0)
    ctr = ctr0 + jnp.uint32(block_rows) * tile.astype(jnp.uint32) + row
    nonce0 = s0[13] ^ nid

    init = []
    for i in range(16):
        if i == 12:
            init.append(ctr)
        elif i == 13:
            init.append(jnp.broadcast_to(nonce0, (block_rows, 1)))
        else:
            init.append(jnp.broadcast_to(s0[i], (block_rows, 1)))

    y_ref[...] = x_ref[...] ^ _keystream_tile(init)[None]


def chacha20_xor_row_blocks(
    x_rows: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_starts: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (n_rows, n_blocks, 16) u32 buffer with per-row keystreams.

    One launch covers the whole buffer with a (rows × block-tiles) grid —
    this is the secure-shuffle fast path, replacing R vmapped single-row
    keystream expansions. Row i, block j draws keystream from
      nonce  = state0 nonce with word 0 XOR nonce_ids[i]
      counter = ctr_starts[i] + j       (absolute; state0[12] is ignored)
    n_blocks must be a multiple of block_rows (ops.py pads).
    """
    n_rows, n_blocks, w = x_rows.shape
    assert w == 16 and x_rows.dtype == jnp.uint32
    assert n_blocks % block_rows == 0, (n_blocks, block_rows)
    assert nonce_ids.shape == (n_rows,) and ctr_starts.shape == (n_rows,)
    grid = (n_rows, n_blocks // block_rows)
    return pl.pallas_call(
        functools.partial(_chacha20_rows_tile_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((16,), lambda i, j: (0,)),  # template state, replicated
            pl.BlockSpec((1,), lambda i, j: (i,)),   # per-row nonce XOR id
            pl.BlockSpec((1,), lambda i, j: (i,)),   # per-row counter start
            pl.BlockSpec((1, block_rows, 16), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, 16), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n_blocks, 16), jnp.uint32),
        interpret=interpret,
    )(state0, nonce_ids, ctr_starts, x_rows)

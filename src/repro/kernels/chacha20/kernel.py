"""Pallas TPU kernel: ChaCha20-CTR keystream generation fused with XOR.

One ARX core (`_keystream_tile`), one data layout:

  * BLOCK-LANE layout — (16, n_blocks) u32: word index on the sublane dim,
    BLOCKS on the 128-wide lane dim. Every entry point lowers onto this
    kernel (`chacha20_xor_row_lanes`): the 16 state words live as (1, L)
    vectors, so every quarter-round step is an L-lane vector op and the
    compiled TPU lowering uses all 128 lanes of each VREG instead of the
    16/128 the historical block-row layout filled (the 7/8-waste the
    ROADMAP named). The per-(row, block) counter is
    `ctr_base[j] + ctr_rowmul[j] * row_ctr` — vector per-block bases, which
    is what lets one launch cover a wire buffer whose blocks belong to
    differently-strided per-leaf counter segments (the coalesced secure
    shuffle).
  * The BLOCK-ROW call surfaces — (n_blocks, 16) single-stream
    `chacha20_xor_blocks` (the `ctr_crypt_array` path) and (R, n_blocks, 16)
    batched `chacha20_xor_row_blocks` — are thin transposing wrappers over
    the lane kernel: block counters become the contiguous special case
    (base = iota, rowmul = 1), so the flat path gets the same full-lane
    utilization as the shuffle hot path and the keystreams cannot drift.

TPU mapping notes:
  * ARX only: add / xor / rotl on u32 — pure VPU lanework, MXU idle; the
    20 rounds are unrolled (no loop-carried scalars).
  * Lane tile = (16, L) u32 = 64·L bytes. Default L=2048 → 128 KiB in +
    128 KiB out per tile, comfortably inside 16 MiB VMEM while long enough
    to amortize control overhead.
  * Counters are derived in-kernel from per-tile base/rowmul vectors:
    the keystream never round-trips through HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto.chacha import CONSTANT_WORDS, _QR_SCHEDULE

DEFAULT_BLOCK_ROWS = 2048
# blocks per lane tile of the (16, n_blocks) layout; multiple of the 128-lane
# VREG width so the compiled TPU lowering is fully lane-aligned
DEFAULT_BLOCK_LANES = 2048


def _keystream_tile(init, axis: int = 1):
    """20 unrolled ARX rounds + feed-forward over 16 state vectors.

    The shared cryptographic core of every tile kernel: any change here
    applies to the single-stream, the batched block-row, and the block-lane
    kernels alike, so their keystreams cannot diverge. `init` is 16 arrays
    of identical shape; the result concatenates the 16 output words along
    `axis` — axis=1 with (B, 1) vectors yields the (B, 16) block-row tile,
    axis=0 with (1, L) vectors yields the (16, L) block-lane tile.
    """

    def rotl(v, n):
        return (v << n) | (v >> (32 - n))

    xs = list(init)
    for _ in range(10):
        for a, b, c, d in _QR_SCHEDULE:
            xa, xb, xc, xd = xs[a], xs[b], xs[c], xs[d]
            xa = xa + xb
            xd = rotl(xd ^ xa, 16)
            xc = xc + xd
            xb = rotl(xb ^ xc, 12)
            xa = xa + xb
            xd = rotl(xd ^ xa, 8)
            xc = xc + xd
            xb = rotl(xb ^ xc, 7)
            xs[a], xs[b], xs[c], xs[d] = xa, xb, xc, xd

    return jnp.concatenate([x + x0 for x, x0 in zip(xs, init)], axis=axis)


def chacha20_xor_blocks(
    x_blocks: jax.Array,
    state0: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR a (n_blocks, 16) u32 message with the keystream.

    `state0` is the 16-word template state (constants, key, counter0, nonce);
    row i uses block counter state0[12] + i. n_blocks must be a multiple of
    block_rows (ops.py pads).

    Since the lane re-tiling this is a thin wrapper over the BLOCK-LANE
    kernel: the message transposes into one (1, 16, n_blocks) lane-layout
    row whose per-block counters are the contiguous special case
    `state0[12] + iota` (nonce id 0 leaves the template nonce untouched), so
    the flat single-stream path — `ctr_crypt_array` via
    `ops.chacha20_xor_words` — runs at full 128-lane VREG utilization
    instead of the 16/128 the retired block-row grid filled.
    """
    n_blocks = x_blocks.shape[0]
    assert x_blocks.shape[1] == 16 and x_blocks.dtype == jnp.uint32
    assert n_blocks % block_rows == 0, (n_blocks, block_rows)
    y = chacha20_xor_row_lanes(
        jnp.swapaxes(x_blocks, 0, 1)[None],       # (1, 16, n_blocks)
        state0,
        jnp.zeros((1,), jnp.uint32),              # nonce XOR id 0
        state0[12:13],                            # per-row ctr operand = counter0
        jnp.arange(n_blocks, dtype=jnp.uint32),   # intra-stream block index
        jnp.ones((n_blocks,), jnp.uint32),        # contiguous stride
        block_lanes=block_rows,
        interpret=interpret,
    )
    return jnp.swapaxes(y[0], 0, 1)


def _chacha20_lanes_tile_kernel(state0_ref, nid_ref, row_ref, base_ref,
                                mul_ref, x_ref, y_ref, *, block_lanes: int):
    """One (row, lane-tile) program of the batched multi-row stream.

    The grid is (n_rows, n_blocks // block_lanes): program (i, j) encrypts
    lane-layout blocks [j*L, (j+1)*L) of wire row i, where the data tile is
    (1, 16, L) — word index on the sublane dim, blocks on the lane dim. The
    row's nonce is the template nonce with word 0 XOR nid_ref[0]; the block
    counter of lane l is `base_ref[l] + mul_ref[l] * row_ref[0]` (absolute —
    state0 word 12 is ignored), so one launch covers blocks whose counters
    advance with different per-segment strides (the coalesced wire). The
    ARX core is the shared `_keystream_tile`, concatenated on the sublane
    axis so each of the 16 output words is a full (1, L) lane vector.
    """
    s0 = state0_ref[...]  # (16,) u32 template: const | key | (ignored) | nonce
    nid = nid_ref[0]
    row_ctr = row_ref[0]

    ctr = (base_ref[...] + mul_ref[...] * row_ctr)[None, :]  # (1, L)
    nonce0 = s0[13] ^ nid

    init = []
    for i in range(16):
        if i == 12:
            init.append(ctr)
        elif i == 13:
            init.append(jnp.broadcast_to(nonce0, (1, block_lanes)))
        else:
            init.append(jnp.broadcast_to(s0[i], (1, block_lanes)))

    y_ref[...] = x_ref[...] ^ _keystream_tile(init, axis=0)[None]


def chacha20_xor_row_lanes(
    x_lanes: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_rows: jax.Array,
    ctr_base: jax.Array,
    ctr_rowmul: jax.Array,
    *,
    block_lanes: int = DEFAULT_BLOCK_LANES,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (n_rows, 16, n_blocks) u32 lane-layout buffer with keystream.

    One launch covers the whole buffer with a (rows × lane-tiles) grid —
    the secure-shuffle fast path. Row i, block j draws keystream from
      nonce   = state0 nonce with word 0 XOR nonce_ids[i]
      counter = ctr_base[j] + ctr_rowmul[j] * ctr_rows[i]
    (absolute; state0[12] is ignored). The vector bases let one launch span
    a coalesced multi-leaf wire: within leaf segment l, ctr_base carries the
    leaf's counter offset + intra-leaf block index and ctr_rowmul the leaf's
    blocks-per-row stride. n_blocks must be a multiple of block_lanes
    (ops.py pads); the legacy contiguous layout is base=iota, rowmul=1,
    ctr_rows=per-row starts.
    """
    n_rows, w, n_blocks = x_lanes.shape
    assert w == 16 and x_lanes.dtype == jnp.uint32
    assert n_blocks % block_lanes == 0, (n_blocks, block_lanes)
    assert nonce_ids.shape == (n_rows,) and ctr_rows.shape == (n_rows,)
    assert ctr_base.shape == (n_blocks,) and ctr_rowmul.shape == (n_blocks,)
    grid = (n_rows, n_blocks // block_lanes)
    return pl.pallas_call(
        functools.partial(_chacha20_lanes_tile_kernel, block_lanes=block_lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((16,), lambda i, j: (0,)),  # template state, replicated
            pl.BlockSpec((1,), lambda i, j: (i,)),   # per-row nonce XOR id
            pl.BlockSpec((1,), lambda i, j: (i,)),   # per-row counter operand
            pl.BlockSpec((block_lanes,), lambda i, j: (j,)),  # per-block base
            pl.BlockSpec((block_lanes,), lambda i, j: (j,)),  # per-block stride
            pl.BlockSpec((1, 16, block_lanes), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 16, block_lanes), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, 16, n_blocks), jnp.uint32),
        interpret=interpret,
    )(state0, nonce_ids, ctr_rows, ctr_base, ctr_rowmul, x_lanes)


def chacha20_xor_row_blocks(
    x_rows: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_starts: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (n_rows, n_blocks, 16) u32 buffer with per-row keystreams.

    Legacy block-row interface kept for the per-leaf differential oracle and
    the kernel test suite; since the lane re-tiling it is a thin wrapper
    that transposes into the (rows, 16, blocks) lane layout and runs the
    SAME `chacha20_xor_row_lanes` kernel with the contiguous-counter
    special case (base=iota, rowmul=1), so the two entry points cannot
    drift. Row i, block j draws keystream from
      nonce  = state0 nonce with word 0 XOR nonce_ids[i]
      counter = ctr_starts[i] + j       (absolute; state0[12] is ignored)
    n_blocks must be a multiple of block_rows (ops.py pads).
    """
    n_rows, n_blocks, w = x_rows.shape
    assert w == 16 and x_rows.dtype == jnp.uint32
    assert n_blocks % block_rows == 0, (n_blocks, block_rows)
    assert nonce_ids.shape == (n_rows,) and ctr_starts.shape == (n_rows,)
    y = chacha20_xor_row_lanes(
        jnp.swapaxes(x_rows, 1, 2),
        state0,
        nonce_ids,
        ctr_starts,
        jnp.arange(n_blocks, dtype=jnp.uint32),
        jnp.ones((n_blocks,), jnp.uint32),
        block_lanes=block_rows,
        interpret=interpret,
    )
    return jnp.swapaxes(y, 1, 2)

"""jit'd wrappers for the chacha20 kernel: padding, word packing, dispatch.

`impl` selects: 'pallas' (interpret on CPU, compiled on TPU), 'jnp' (oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.crypto import ctr as _ctr
from repro.crypto.chacha import CONSTANT_WORDS
from repro.kernels.chacha20 import ref as _ref
from repro.kernels.chacha20.kernel import (
    DEFAULT_BLOCK_ROWS,
    chacha20_xor_blocks,
    chacha20_xor_row_blocks,
)


def make_state0(key_words, nonce_words, counter0) -> jax.Array:
    """Build the 16-word template state: constants | key | counter | nonce."""
    const = jnp.array(CONSTANT_WORDS, dtype=jnp.uint32)
    kw = jnp.asarray(key_words, jnp.uint32)
    nw = jnp.asarray(nonce_words, jnp.uint32)
    c = jnp.asarray(counter0, jnp.uint32).reshape(1)
    return jnp.concatenate([const, kw, c, nw])


@functools.partial(jax.jit, static_argnames=("impl", "block_rows", "interpret"))
def chacha20_xor_words(
    words: jax.Array,
    state0: jax.Array,
    *,
    impl: str = "pallas",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR a flat (n,) u32 word stream with the keystream starting at state0."""
    n = words.shape[0]
    n_blocks = -(-n // 16)
    if impl == "jnp" or n_blocks == 0:
        from repro.crypto.chacha import chacha20_keystream_words

        ks = chacha20_keystream_words(state0[4:12], state0[13:16], state0[12], n)
        return words ^ ks
    rows = block_rows
    if n_blocks < rows:
        # Small payloads: shrink tile to the padded block count (≥ 8 rows).
        rows = max(8, 1 << (n_blocks - 1).bit_length())
    pad_blocks = (-n_blocks) % rows
    total = (n_blocks + pad_blocks) * 16
    x = jnp.concatenate([words, jnp.zeros((total - n,), jnp.uint32)]).reshape(-1, 16)
    y = chacha20_xor_blocks(x, state0, block_rows=rows, interpret=interpret)
    return y.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("impl", "block_rows", "interpret"))
def chacha20_xor_rows(
    words: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_starts: jax.Array,
    *,
    impl: str = "pallas",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (R, n_words) u32 wire buffer with per-row keystreams.

    Row i uses nonce = state0 nonce with word 0 XOR nonce_ids[i] and block
    counters starting at ctr_starts[i] (absolute — state0 word 12 is
    ignored). This is the secure-shuffle entry point: 'pallas' covers the
    whole buffer in ONE launch gridded over rows × block tiles; 'jnp' is the
    bit-exact vmapped oracle kept for differential testing.
    """
    r, n = words.shape
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_starts = jnp.asarray(ctr_starts, jnp.uint32)
    n_blocks = -(-n // 16)
    if impl == "jnp" or n_blocks == 0 or r == 0:
        from repro.crypto.chacha import chacha20_keystream_words

        def one(row_words, nid, ctr0):
            nonce = state0[13:16].at[0].set(state0[13] ^ nid)
            return row_words ^ chacha20_keystream_words(state0[4:12], nonce, ctr0, n)

        return jax.vmap(one)(words, nonce_ids, ctr_starts)
    rows = block_rows
    if n_blocks < rows:
        # Small rows (the common shuffle case): one tile per row, >= 8 blocks.
        rows = max(8, 1 << (n_blocks - 1).bit_length())
    pad_blocks = (-n_blocks) % rows
    total = (n_blocks + pad_blocks) * 16
    x = jnp.concatenate(
        [words, jnp.zeros((r, total - n), jnp.uint32)], axis=1
    ).reshape(r, -1, 16)
    y = chacha20_xor_row_blocks(
        x, state0, nonce_ids, ctr_starts, block_rows=rows, interpret=interpret
    )
    return y.reshape(r, -1)[:, :n]


def ctr_crypt_array(
    x: jax.Array,
    key_words,
    nonce_words,
    counter0=0,
    *,
    impl: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    """Encrypt/decrypt an arbitrary-dtype array via the kernel (XOR stream)."""
    shape, dtype = x.shape, x.dtype
    words, pad = _ctr._to_words(x)
    state0 = make_state0(key_words, nonce_words, counter0)
    out = chacha20_xor_words(words, state0, impl=impl, interpret=interpret)
    return _ctr._from_words(out, shape, dtype, pad)

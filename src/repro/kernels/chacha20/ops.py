"""jit'd wrappers for the chacha20 kernel: padding, word packing, dispatch.

`impl` selects: 'pallas' (interpret on CPU, compiled on TPU), 'jnp' (oracle).

Since the lane re-tiling, every rows-style entry point lowers onto the
(16, n_blocks) BLOCK-LANE kernel (`kernel.chacha20_xor_row_lanes`): the
wrappers here pad the block count to a lane-tile multiple, transpose into
lane layout, launch once, and slice the pad back off. Kernel-side lane pad
never reaches the caller (and therefore never reaches a shuffle wire); it
exists only so the compiled TPU lowering works on full 128-lane VREGs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.crypto import ctr as _ctr
from repro.crypto.chacha import CONSTANT_WORDS, chacha20_block_words
from repro.kernels.chacha20 import ref as _ref
from repro.kernels.chacha20.kernel import (
    DEFAULT_BLOCK_LANES,
    DEFAULT_BLOCK_ROWS,
    chacha20_xor_blocks,
    chacha20_xor_row_lanes,
)


def make_state0(key_words, nonce_words, counter0) -> jax.Array:
    """Build the 16-word template state: constants | key | counter | nonce."""
    const = jnp.array(CONSTANT_WORDS, dtype=jnp.uint32)
    kw = jnp.asarray(key_words, jnp.uint32)
    nw = jnp.asarray(nonce_words, jnp.uint32)
    c = jnp.asarray(counter0, jnp.uint32).reshape(1)
    return jnp.concatenate([const, kw, c, nw])


def _lane_tile(n_blocks: int, block_lanes: int, interpret: bool) -> int:
    """Lanes per tile for a lane-layout launch (`_xor_lanes` pads to it).

    Interpret mode always takes ONE tile spanning the whole (8-aligned)
    block count: the emulator walks grid steps through a slow per-step loop
    (measured ~25x at 2 tiles vs 1), small payloads should pad to 8 blocks
    rather than burn 40x the ARX work on a 3-block wire, and the VMEM
    budget `block_lanes` protects does not bind off-accelerator. Compiled
    lowerings tile at `block_lanes` (multiple of the 128-wide VREG) and pad
    small payloads to full 128-lane multiples.
    """
    if interpret:
        return max(8, -(-n_blocks // 8) * 8)
    if n_blocks >= block_lanes:
        return block_lanes
    return max(128, -(-n_blocks // 128) * 128)


def _xor_lanes(x_blocks, state0, nonce_ids, ctr_rows, ctr_base, ctr_rowmul,
               lanes: int, interpret: bool):
    """Pad (r, n_blocks, 16) to a lane-tile multiple, launch, un-pad."""
    r, n_blocks, _ = x_blocks.shape
    pad = -(-n_blocks // lanes) * lanes - n_blocks
    if pad:
        x_blocks = jnp.concatenate(
            [x_blocks, jnp.zeros((r, pad, 16), jnp.uint32)], axis=1)
        ctr_base = jnp.concatenate([ctr_base, jnp.zeros((pad,), jnp.uint32)])
        ctr_rowmul = jnp.concatenate([ctr_rowmul, jnp.zeros((pad,), jnp.uint32)])
    y = chacha20_xor_row_lanes(
        jnp.swapaxes(x_blocks, 1, 2), state0, nonce_ids, ctr_rows,
        ctr_base, ctr_rowmul, block_lanes=lanes, interpret=interpret)
    return jnp.swapaxes(y, 1, 2)[:, :n_blocks, :]


@functools.partial(jax.jit, static_argnames=("impl", "block_rows", "interpret"))
def chacha20_xor_words(
    words: jax.Array,
    state0: jax.Array,
    *,
    impl: str = "pallas",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """XOR a flat (n,) u32 word stream with the keystream starting at state0.

    Block i draws counter state0[12] + i. Lowers onto the BLOCK-LANE kernel
    as one single-row lane-layout launch (contiguous counters: base = iota,
    rowmul = 1) with the same `_lane_tile` policy as the shuffle wrappers —
    interpret mode takes ONE tile over the whole padded block count, so the
    flat `ctr_crypt_array` path shares both the full-lane compiled lowering
    and the fast interpret shape with the wire hot path.
    """
    n = words.shape[0]
    n_blocks = -(-n // 16)
    if impl == "jnp" or n_blocks == 0:
        from repro.crypto.chacha import chacha20_keystream_words

        ks = chacha20_keystream_words(state0[4:12], state0[13:16], state0[12], n)
        return words ^ ks
    lanes = _lane_tile(n_blocks, block_rows, interpret)
    x = jnp.concatenate(
        [words, jnp.zeros((n_blocks * 16 - n,), jnp.uint32)]).reshape(1, -1, 16)
    y = _xor_lanes(x, state0,
                   jnp.zeros((1,), jnp.uint32),             # nonce XOR id 0
                   state0[12:13],                           # ctr operand = counter0
                   jnp.arange(n_blocks, dtype=jnp.uint32),  # contiguous block index
                   jnp.ones((n_blocks,), jnp.uint32),
                   lanes, interpret)
    return y.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("impl", "block_lanes", "interpret"))
def chacha20_xor_rows(
    words: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_starts: jax.Array,
    *,
    impl: str = "pallas",
    block_lanes: int = DEFAULT_BLOCK_LANES,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (R, n_words) u32 wire buffer with per-row keystreams.

    Row i uses nonce = state0 nonce with word 0 XOR nonce_ids[i] and block
    counters starting at ctr_starts[i] (absolute — state0 word 12 is
    ignored). This is the per-leaf secure-shuffle entry point: 'pallas'
    covers the whole buffer in ONE lane-tiled launch gridded over rows ×
    lane tiles (the contiguous-counter special case of the coalesced
    kernel: base=iota, rowmul=1); 'jnp' is the bit-exact vmapped oracle
    kept for differential testing.
    """
    r, n = words.shape
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_starts = jnp.asarray(ctr_starts, jnp.uint32)
    n_blocks = -(-n // 16)
    if impl == "jnp" or n_blocks == 0 or r == 0:
        from repro.crypto.chacha import chacha20_keystream_words

        def one(row_words, nid, ctr0):
            nonce = state0[13:16].at[0].set(state0[13] ^ nid)
            return row_words ^ chacha20_keystream_words(state0[4:12], nonce, ctr0, n)

        return jax.vmap(one)(words, nonce_ids, ctr_starts)
    lanes = _lane_tile(n_blocks, block_lanes, interpret)
    x = jnp.concatenate(
        [words, jnp.zeros((r, n_blocks * 16 - n), jnp.uint32)], axis=1
    ).reshape(r, n_blocks, 16)
    y = _xor_lanes(x, state0, nonce_ids, ctr_starts,
                   jnp.arange(n_blocks, dtype=jnp.uint32),
                   jnp.ones((n_blocks,), jnp.uint32), lanes, interpret)
    return y.reshape(r, -1)[:, :n]


@functools.partial(jax.jit, static_argnames=("impl", "block_lanes", "interpret"))
def chacha20_xor_rows_coalesced(
    words: jax.Array,
    state0: jax.Array,
    nonce_ids: jax.Array,
    ctr_rows: jax.Array,
    ctr_base: jax.Array,
    ctr_rowmul: jax.Array,
    *,
    impl: str = "pallas",
    block_lanes: int = DEFAULT_BLOCK_LANES,
    interpret: bool = True,
) -> jax.Array:
    """XOR an (R, 16·n_blocks) u32 COALESCED wire with per-row keystreams.

    The coalesced secure-shuffle entry point: the whole multi-leaf wire
    (every leaf's block-aligned segment concatenated on the word axis)
    travels through ONE launch. Block j of row i draws keystream from
      nonce   = state0 nonce with word 0 XOR nonce_ids[i]
      counter = ctr_base[j] + ctr_rowmul[j] * ctr_rows[i]
    (absolute; state0 word 12 is ignored). The per-block vectors encode the
    per-leaf counter segments of `core/shuffle.py`'s layout — base carries
    leaf counter offset + intra-leaf block index, rowmul the leaf's
    blocks-per-row stride — reproducing the per-leaf path's (key, nonce,
    counter) assignment bit-for-bit. 'jnp' is the vmapped block oracle kept
    for differential testing. n_words must be a multiple of 16 (the wire is
    block-aligned by construction).
    """
    r, n = words.shape
    if n % 16:
        raise ValueError(f"coalesced wire must be block-aligned, got n_words={n}")
    n_blocks = n // 16
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_rows = jnp.asarray(ctr_rows, jnp.uint32)
    ctr_base = jnp.asarray(ctr_base, jnp.uint32)
    ctr_rowmul = jnp.asarray(ctr_rowmul, jnp.uint32)
    if impl == "jnp" or n_blocks == 0 or r == 0:

        def one(row_words, nid, rc):
            nonce = state0[13:16].at[0].set(state0[13] ^ nid)
            counters = ctr_base + ctr_rowmul * rc
            ks = chacha20_block_words(state0[4:12], counters, nonce)
            return row_words ^ ks.reshape(-1)

        return jax.vmap(one)(words, nonce_ids, ctr_rows)
    lanes = _lane_tile(n_blocks, block_lanes, interpret)
    y = _xor_lanes(words.reshape(r, n_blocks, 16), state0, nonce_ids, ctr_rows,
                   ctr_base, ctr_rowmul, lanes, interpret)
    return y.reshape(r, -1)


def ctr_crypt_array(
    x: jax.Array,
    key_words,
    nonce_words,
    counter0=0,
    *,
    impl: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    """Encrypt/decrypt an arbitrary-dtype array via the kernel (XOR stream)."""
    shape, dtype = x.shape, x.dtype
    words, pad = _ctr._to_words(x)
    state0 = make_state0(key_words, nonce_words, counter0)
    out = chacha20_xor_words(words, state0, impl=impl, interpret=interpret)
    return _ctr._from_words(out, shape, dtype, pad)

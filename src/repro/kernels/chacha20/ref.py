"""Pure-jnp oracle for the chacha20 Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.chacha import chacha20_block_words


def chacha20_xor_blocks_ref(x_blocks: jax.Array, state0: jax.Array) -> jax.Array:
    """Reference: XOR (n_blocks, 16) u32 message with keystream from state0."""
    n = x_blocks.shape[0]
    key_words = state0[4:12]
    nonce_words = state0[13:16]
    counters = state0[12] + jnp.arange(n, dtype=jnp.uint32)
    ks = chacha20_block_words(key_words, counters, nonce_words)
    return x_blocks ^ ks

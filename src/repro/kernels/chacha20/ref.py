"""Pure-jnp oracle for the chacha20 Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto.chacha import chacha20_block_words


def chacha20_xor_blocks_ref(x_blocks: jax.Array, state0: jax.Array) -> jax.Array:
    """Reference: XOR (n_blocks, 16) u32 message with keystream from state0."""
    n = x_blocks.shape[0]
    key_words = state0[4:12]
    nonce_words = state0[13:16]
    counters = state0[12] + jnp.arange(n, dtype=jnp.uint32)
    ks = chacha20_block_words(key_words, counters, nonce_words)
    return x_blocks ^ ks


def chacha20_xor_row_blocks_ref(x_rows, state0, nonce_ids, ctr_starts):
    """Reference for the batched rows kernel: (R, n_blocks, 16) u32 buffer,
    row i using nonce word 0 XOR nonce_ids[i] and absolute counter start
    ctr_starts[i] (state0 word 12 ignored)."""
    n_blocks = x_rows.shape[1]
    key_words = state0[4:12]

    def one(row, nid, ctr0):
        nonce = state0[13:16].at[0].set(state0[13] ^ nid)
        counters = ctr0 + jnp.arange(n_blocks, dtype=jnp.uint32)
        return row ^ chacha20_block_words(key_words, counters, nonce)

    return jax.vmap(one)(x_rows, jnp.asarray(nonce_ids, jnp.uint32),
                         jnp.asarray(ctr_starts, jnp.uint32))


def chacha20_xor_row_lanes_ref(x_lanes, state0, nonce_ids, ctr_rows,
                               ctr_base, ctr_rowmul):
    """Reference for the lane-layout kernel: (R, 16, n_blocks) u32 buffer,
    row i / block j using nonce word 0 XOR nonce_ids[i] and absolute counter
    ctr_base[j] + ctr_rowmul[j] * ctr_rows[i] (state0 word 12 ignored)."""
    key_words = state0[4:12]
    ctr_base = jnp.asarray(ctr_base, jnp.uint32)
    ctr_rowmul = jnp.asarray(ctr_rowmul, jnp.uint32)

    def one(row, nid, rc):
        nonce = state0[13:16].at[0].set(state0[13] ^ nid)
        counters = ctr_base + ctr_rowmul * rc
        return row ^ chacha20_block_words(key_words, counters, nonce).T

    return jax.vmap(one)(x_lanes, jnp.asarray(nonce_ids, jnp.uint32),
                         jnp.asarray(ctr_rows, jnp.uint32))

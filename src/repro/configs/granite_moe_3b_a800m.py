"""granite-moe-3b-a800m — [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

Assignment: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e
top-8. (The assignment line also mentions "32 experts"; we follow the primary
"MoE 40e top-8" spec and record the discrepancy here.)
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49155,
    n_experts=40,
    n_experts_per_tok=8,
    attn_chunk=2048,
    moe_remat="save_shuffle",  # §Perf cell C: -14% mem, -17% coll, -28% compute
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

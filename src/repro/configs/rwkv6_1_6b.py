"""rwkv6-1.6b "Finch" — [arXiv:2404.05892; unverified]. Attention-free.

24L d_model=2048 d_ff=7168 vocab=65536; data-dependent per-channel decay.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    source="arXiv:2404.05892; unverified",
)

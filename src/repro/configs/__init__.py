"""Config registry: get_config("<arch-id>") for every assigned architecture."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_skips

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "qwen2-moe-a2.7b",
    "whisper-base",
    "mistral-large-123b",
    "deepseek-67b",
    "glm4-9b",
    "granite-20b",
    "zamba2-1.2b",
    "chameleon-34b",
    "rwkv6-1.6b",
]

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-base": "whisper_base",
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-67b": "deepseek_67b",
    "glm4-9b": "glm4_9b",
    "granite-20b": "granite_20b",
    "zamba2-1.2b": "zamba2_1_2b",
    "chameleon-34b": "chameleon_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ARCH_IDS", "get_config", "get_shape", "SHAPES", "shape_skips", "ArchConfig"]

"""chameleon-34b — [arXiv:2405.09818; unverified]. Early-fusion VLM.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 with VQ image tokens
in-vocab; qk-norm per the paper. The VQ tokenizer frontend is a STUB:
input_specs provides token ids (image tokens are ordinary vocab entries).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    attn_chunk=2048,
    source="arXiv:2405.09818; unverified",
)

"""qwen2-moe-a2.7b — [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, 4 shared + 60
routed experts top-4 (shared-expert hidden = 4x1408 = 5632).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    shared_d_ff=5632,
    vocab_size=151936,
    n_experts=60,
    n_experts_per_tok=4,
    n_shared_experts=4,
    attn_chunk=2048,
    moe_remat="save_shuffle",  # §Perf cell C: -14% mem, -17% coll, -28% compute
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)

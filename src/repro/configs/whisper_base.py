"""whisper-base — [arXiv:2212.04356; unverified].

6L d_model=512 8H d_ff=2048 vocab=51865, encoder-decoder; the conv/mel
frontend is a STUB: input_specs provides precomputed frame embeddings
(B, 1500, d_model), the standard 30 s window.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    n_encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    attn_chunk=2048,
    source="arXiv:2212.04356; unverified",
)

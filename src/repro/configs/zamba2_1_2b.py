"""zamba2-1.2b — [arXiv:2411.15242; hf]. Mamba2 backbone + shared attn.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64; one
weight-shared attention(+MLP) block applied every 6 mamba layers
(simplified vs upstream: no per-invocation LoRA, no embedding concat —
noted in DESIGN.md).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    attn_chunk=2048,
    source="arXiv:2411.15242; hf",
)

"""Architecture + shape configuration schema (one config file per arch)."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    shared_d_ff: int = 0
    moe_dispatch: str = "shuffle"  # "shuffle" (paper technique) | "dense"
    capacity_factor: float = 1.25
    secure_moe: bool = False  # encrypt expert all_to_all payloads

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: shared attention block every N ssm layers

    # attention
    rope_theta: float = 10000.0
    causal: bool = True
    qk_norm: bool = False
    attn_chunk: int = 0  # 0 -> dense attention; else query-chunked (memory-safe)

    # encoder-decoder
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder frames (audio frontend stub)

    # misc
    norm: str = "rmsnorm"
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "sqrt"  # sqrt (two-level) | full | dots | none
    # perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    softmax_dtype: str = "float32"  # "bfloat16": halve attention-score bytes
    moe_remat: str = "full"  # "save_shuffle": don't replay all_to_all in bwd
    shard_strategy: str = "tp"  # "dp_sp": replicate weights, shard sequence
    wkv_impl: str = "blocked"  # "scan": paper-faithful per-token recurrence
    serve_bf16_params: bool = False  # serve with bf16 weights (no f32 masters)
    moe_fsdp: bool = True  # False: replicate expert weights across dp (no per-layer AG)
    source: str = ""  # provenance bracket from the assignment

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 (Megatron-style) so the
        vocab dim shards evenly over any mesh axis; pad logits are masked."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear attention)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2 + (2 if self.attn_every else 0)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            n_experts_per_tok=min(self.n_experts_per_tok, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_every=2 if self.attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


# The assigned shape set (applies to every LM arch; skips handled per-arch).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_skips(arch: ArchConfig) -> dict[str, str]:
    """Cells skipped for this arch, with reasons (recorded in EXPERIMENTS.md)."""
    skips = {}
    if not arch.sub_quadratic:
        skips["long_500k"] = "full-attention arch: 500k KV decode requires sub-quadratic attention (DESIGN.md §5)"
    return skips

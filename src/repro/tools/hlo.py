"""HLO text-cost engine: loop-aware FLOPs / bytes / collective accounting.

XLA's `compiled.cost_analysis()` visits a while body ONCE — a scan-over-88-
layers model would be undercounted 88x. This parser rebuilds the call graph
from optimized HLO text, reads each while's `backend_config known_trip_count`
(falling back to the loop condition's compare constant), and attributes costs
recursively through while bodies, fusions, calls and conditionals.

Accounting conventions (mirroring HloCostAnalysis where it is sane):
  dot            flops = 2 · prod(out dims) · prod(lhs contracting dims)
  bytes          Σ (operand + output bytes) per instruction, with zero-cost
                 bookkeeping ops (tuple/gte/parameter/constant/bitcast)
                 excluded; fusion-internal intermediates are free (only the
                 fusion node's boundary bytes count)
  collectives    operand payload bytes + a ring model for per-link traffic:
                   all-gather          B·(g-1)
                   all-reduce          2·B·(g-1)/g
                   reduce-scatter      B·(g-1)/g
                   all-to-all          B·(g-1)/g
                   collective-permute  B
  conditional    max-cost branch (upper bound; a warning is recorded)

The compiled module is the per-device SPMD program, so everything here is
already per-chip.
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

ZERO_COST = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "opt-barrier"}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_REF = re.compile(r"%([\w\.\-]+)")


def _shape_list(type_str: str):
    """All dtype[dims] pairs in a type string (tuple types give several)."""
    return [(d, [int(x) for x in dims.split(",")] if dims else [])
            for d, dims in _SHAPE_RE.findall(type_str)]


def _bytes_of(shapes) -> int:
    total = 0
    for d, dims in shapes:
        n = 1
        for x in dims:
            n *= x
        total += n * DTYPE_BYTES.get(d, 4)
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: list
    operand_names: list
    attrs: str

    @property
    def out_bytes(self):
        return _bytes_of(self.out_shapes)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_dot: float = 0.0  # dot operand/output traffic only (TPU-optimistic LB)
    link_bytes: float = 0.0
    coll_payload: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(float))
    warnings: set = field(default_factory=set)

    def add(self, o: "Costs", mult: float = 1.0):
        self.flops += o.flops * mult
        self.bytes += o.bytes * mult
        self.bytes_dot += o.bytes_dot * mult
        self.link_bytes += o.link_bytes * mult
        for k, v in o.coll_payload.items():
            self.coll_payload[k] += v * mult
        for k, v in o.coll_count.items():
            self.coll_count[k] += v * mult
        self.warnings |= o.warnings


def _parse_instr(line: str) -> Instr | None:
    m = _INSTR.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # output type: leading tuple "(...)" or single "dtype[dims]{layout}"
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, tail = rest[: i + 1], rest[i + 1 :]
                    break
        else:
            return None
    else:
        sm = _SHAPE_RE.match(rest)
        if not sm:
            return None
        end = sm.end()
        if end < len(rest) and rest[end] == "{":  # layout annotation
            end = rest.find("}", end) + 1
        type_str, tail = rest[:end], rest[end:]
    om = _OPCODE.search(tail)
    if not om:
        return None
    opcode = om.group(1)
    # operands: balanced parens right after the opcode
    start = om.end() - 1
    depth = 0
    endp = len(tail)
    for i in range(start, len(tail)):
        if tail[i] == "(":
            depth += 1
        elif tail[i] == ")":
            depth -= 1
            if depth == 0:
                endp = i
                break
    inner = tail[start + 1 : endp]
    attrs = tail[endp + 1 :]
    return Instr(name, opcode, _shape_list(type_str), _REF.findall(inner), attrs)


def _split_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    order: list[str] = []
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                order.append(cur)
        else:
            if line.strip() == "}":
                cur = None
                continue
            ins = _parse_instr(line)
            if ins is not None:
                comps[cur].append(ins)
    return comps


def _trip_count(instr: Instr, comps) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.attrs)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=\s*%?([\w\.\-]+)", instr.attrs)
    if cm and cm.group(1) in comps:
        consts = {}
        for ins in comps[cm.group(1)]:
            if ins.opcode == "constant":
                c = re.search(r"constant\((\d+)\)", f"constant({ins.attrs})")
                # constants carry their value in the operand slot of the text;
                # re-parse from the raw attrs is unreliable -> skip
        return None
    return None


def _group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return default


class _Analyzer:
    def __init__(self, comps, default_group: int):
        self.comps = comps
        self.g = default_group
        self.memo: dict[str, Costs] = {}

    def comp_costs(self, name: str) -> Costs:
        if name in self.memo:
            return self.memo[name]
        self.memo[name] = Costs()  # cycle guard
        symtab = {i.name: i for i in self.comps.get(name, [])}
        c = Costs()
        for ins in self.comps.get(name, []):
            self.instr_costs(ins, symtab, c)
        self.memo[name] = c
        return c

    def _operand_bytes(self, ins: Instr, symtab) -> float:
        """Operand traffic. For fusions, an operand consumed ONLY via
        dynamic-slice inside the fused computation is charged the SLICE size,
        not the whole buffer (a scan body reads one layer's stack slice, not
        the full stacked tensor)."""
        slice_sizes = None
        if ins.opcode == "fusion":
            m = re.search(r"calls=\s*%?([\w\.\-]+)", ins.attrs)
            fused = self.comps.get(m.group(1)) if m else None
            if fused:
                params = [fi for fi in fused if fi.opcode == "parameter"]
                slice_sizes = []
                for pi in params:
                    users = [fi for fi in fused if pi.name in fi.operand_names]
                    if users and all(u.opcode == "dynamic-slice" for u in users):
                        slice_sizes.append(sum(u.out_bytes for u in users))
                    else:
                        slice_sizes.append(None)
        total = 0.0
        for i, r in enumerate(ins.operand_names):
            if r not in symtab:
                continue
            if slice_sizes is not None and i < len(slice_sizes) and slice_sizes[i] is not None:
                total += slice_sizes[i]
            else:
                total += symtab[r].out_bytes
        return total

    def instr_costs(self, ins: Instr, symtab, c: Costs):
        op = ins.opcode
        if op in ZERO_COST:
            return
        if op == "while":
            trips = _trip_count(ins, self.comps)
            if trips is None:
                trips = 1
                c.warnings.add(f"unknown trip count: {ins.name}")
            bm = re.search(r"body=\s*%?([\w\.\-]+)", ins.attrs)
            if bm and bm.group(1) in self.comps:
                c.add(self.comp_costs(bm.group(1)), trips)
            return
        if op == "conditional":
            branches = re.findall(r"%([\w\.\-]+)", ins.attrs)
            branch_costs = [self.comp_costs(b) for b in branches if b in self.comps]
            if branch_costs:
                best = max(branch_costs, key=lambda x: x.flops + x.bytes)
                c.add(best)
                c.warnings.add("conditional: max-cost branch attributed")
            return

        out_b = ins.out_bytes
        opnd_b = self._operand_bytes(ins, symtab)
        c.bytes += out_b + opnd_b
        if op == "dot":
            c.bytes_dot += out_b + opnd_b

        if op == "dot":
            k = 1.0
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]+)\}", ins.attrs)
            lhs = symtab.get(ins.operand_names[0]) if ins.operand_names else None
            if cm and lhs is not None and lhs.out_shapes:
                dims = lhs.out_shapes[0][1]
                for ci in cm.group(1).split(","):
                    ci = int(ci)
                    if ci < len(dims):
                        k *= dims[ci]
            out_elems = 0
            for d, dims in ins.out_shapes:
                n = 1
                for x in dims:
                    n *= x
                out_elems += n
            c.flops += 2.0 * out_elems * k
            return
        if op == "custom-call" and ("matmul" in ins.attrs or "dot" in ins.attrs):
            c.warnings.add("custom-call matmul: flops estimated from operands")
            if len(ins.operand_names) >= 2:
                a = symtab.get(ins.operand_names[0])
                if a and a.out_shapes and a.out_shapes[0][1]:
                    k = a.out_shapes[0][1][-1]
                    out_elems = sum(
                        _bytes_of([(d, dims)]) / DTYPE_BYTES.get(d, 4)
                        for d, dims in ins.out_shapes
                    )
                    c.flops += 2.0 * out_elems * k
            return
        if any(op.startswith(base) for base in COLLECTIVES):
            if op.endswith("-done"):
                c.bytes -= out_b + opnd_b  # counted at -start
                return
            base = next(b for b in COLLECTIVES if op.startswith(b))
            payload = opnd_b
            g = _group_size(ins.attrs, self.g)
            link = {
                "all-reduce": 2.0 * payload * (g - 1) / max(g, 1),
                "all-gather": payload * (g - 1),
                "reduce-scatter": payload * (g - 1) / max(g, 1),
                "all-to-all": payload * (g - 1) / max(g, 1),
                "collective-permute": payload,
            }[base]
            c.coll_payload[base] += payload
            c.coll_count[base] += 1
            c.link_bytes += link
            return
        # fusions / calls / reduces: recurse for flops & collectives, but the
        # boundary bytes above already cover memory traffic
        for attr in ("calls", "to_apply"):
            m = re.search(attr + r"=\s*%?([\w\.\-]+)", ins.attrs)
            if m and m.group(1) in self.comps:
                sub = self.comp_costs(m.group(1))
                c.flops += sub.flops
                c.link_bytes += sub.link_bytes
                for k2, v in sub.coll_payload.items():
                    c.coll_payload[k2] += v
                for k2, v in sub.coll_count.items():
                    c.coll_count[k2] += v
                c.warnings |= sub.warnings


def parse_hlo_costs(hlo_text: str, default_group: int = 1) -> dict:
    comps = _split_computations(hlo_text)
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
    entry = m.group(1) if m else None
    if entry not in comps:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    if entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}, "collective_counts": {},
                "link_bytes": 0, "warnings": ["no entry computation found"]}
    an = _Analyzer(comps, default_group)
    c = an.comp_costs(entry)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "bytes_dot": c.bytes_dot,
        "collectives": dict(sorted(c.coll_payload.items())),
        "collective_counts": {k: int(v) for k, v in sorted(c.coll_count.items())},
        "link_bytes": c.link_bytes,
        "warnings": sorted(c.warnings),
    }


def collective_bytes(hlo_text: str, default_group: int = 1) -> dict:
    return parse_hlo_costs(hlo_text, default_group)


# --- roofline -------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link


def roofline_terms(cost_analysis: dict, parsed: dict, n_chips: int) -> dict:
    """Three terms in seconds, per chip, from the parsed (loop-aware) costs."""
    flops = parsed.get("flops") or cost_analysis.get("flops") or 0.0
    bts = parsed.get("bytes") or cost_analysis.get("bytes accessed") or 0.0
    link = parsed.get("link_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bts / HBM_BW
    t_coll = link / LINK_BW
    dom = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        # TPU-optimistic lower bound: only matmul operand/output HBM traffic
        # (CPU HLO's fusion granularity inflates the boundary-bytes count)
        "memory_lb_s": parsed.get("bytes_dot", 0.0) / HBM_BW,
        "collective_s": t_coll,
        "dominant": dom,
        "flops_per_chip": flops,
        "bytes_per_chip": bts,
        "link_bytes_per_chip": link,
        "warnings": parsed.get("warnings", []),
    }

"""Analytic model FLOPs (6·N·D / 2·N·D) + report generation for EXPERIMENTS.md."""

from __future__ import annotations

import json
import os

from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape, shape_skips
from repro.models.ssm import HEAD_P, ssm_dims
from repro.tools.hlo import HBM_BW, LINK_BW, PEAK_FLOPS


def param_counts(cfg) -> tuple[int, int]:
    """(total, active-per-token) parameter counts, embeddings included once."""
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    dh = cfg.head_dim
    attn = d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh + cfg.n_heads * dh * d
    emb = cfg.padded_vocab * d

    if cfg.family == "moe":
        f = cfg.moe_d_ff or ff
        router = d * cfg.n_experts
        expert = 3 * d * f
        shared = (3 * d * cfg.shared_d_ff + d) if cfg.n_shared_experts else 0
        layer_total = attn + router + cfg.n_experts * expert + shared
        layer_active = attn + router + cfg.n_experts_per_tok * expert + shared
        total = emb + l * layer_total
        active = emb + l * layer_active
        return total, active
    if cfg.family == "ssm":  # rwkv6
        layer = 5 * d * d + d * 32 + 32 * d + 2 * d * ff + d * d
        total = emb + l * layer
        return total, total
    if cfg.family == "hybrid":
        d_inner, h = ssm_dims(cfg)
        n = cfg.ssm_state
        mamba = d * (2 * d_inner + 2 * n + h) + d_inner * d + cfg.ssm_conv * d_inner
        shared_attn = attn + 3 * d * ff
        total = emb + l * mamba + shared_attn
        return total, total
    if cfg.family == "audio":
        enc_layer = attn + 3 * d * ff
        dec_layer = 2 * attn + 3 * d * ff
        total = emb + cfg.n_encoder_layers * enc_layer + l * dec_layer
        return total, total
    layer = attn + 3 * d * ff  # dense / vlm
    total = emb + l * layer
    return total, total


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for inference."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per sequence


def one_sentence(dom: str, cfg, shape) -> str:
    if dom == "compute":
        return "at the compute roofline; only kernel-level fusion moves it"
    if dom == "collective":
        return ("shrink/overlap collectives: larger per-chip shards, bf16 wire "
                "payloads, or fewer TP boundaries per layer")
    if shape.kind == "decode":
        return "HBM-bound by design (KV/state streaming) — near the decode roofline"
    return ("reduce HBM round-trips: flash-style attention fusion and less "
            "remat recompute of wide activations")


def generate_report(report_path: str) -> dict:
    """Digest reports/dryrun.json into the §Dry-run/§Roofline tables."""
    with open(report_path) as f:
        results = json.load(f)
    rows = []
    for mesh_name in ("single_pod", "multi_pod"):
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape_name in SHAPES:
                key = f"{arch}|{shape_name}|{mesh_name}"
                r = results.get(key)
                if r is None:
                    continue
                shape = get_shape(shape_name)
                if r["status"] == "SKIP":
                    rows.append({"key": key, "status": "SKIP", "reason": r["reason"],
                                 "mesh": mesh_name, "arch": arch, "shape": shape_name})
                    continue
                if r["status"] != "OK":
                    rows.append({"key": key, "status": "FAIL", "mesh": mesh_name,
                                 "arch": arch, "shape": shape_name})
                    continue
                rf = r["roofline"]
                mf = model_flops(cfg, shape)
                hlo_global = rf["flops_per_chip"] * r["n_chips"]
                rows.append({
                    "key": key, "status": "OK", "mesh": mesh_name, "arch": arch,
                    "shape": shape_name, "n_chips": r["n_chips"],
                    "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
                    "collective_s": rf["collective_s"], "dominant": rf["dominant"],
                    "model_flops": mf, "hlo_flops_global": hlo_global,
                    "useful_ratio": mf / hlo_global if hlo_global else 0.0,
                    "peak_gib": r["memory"].get("peak_per_device", 0) / 2**30,
                    "collectives": r.get("collectives", {}).get("collective_counts", {}),
                    "t_compile_s": r["t_compile_s"],
                    "note": one_sentence(rf["dominant"], cfg, shape),
                })
    return {"rows": rows}

"""Jaxpr introspection helpers: count primitives across nested call sites.

Used by the shuffle benchmarks and tests to PROVE structural claims about a
traced program — e.g. that a coalesced secure round contains exactly one
`all_to_all` and two `pallas_call` keystream launches — instead of trusting
the accounting that produced them. Counting happens on the jaxpr, not the
lowered HLO: on a single-device mesh XLA may simplify a collective away,
but the traced program is what scales to a real mesh.
"""

from __future__ import annotations

from typing import Any, Iterator

import jax

try:  # modern jax moved core under jax.extend
    from jax.extend import core as _core  # type: ignore
    _ = _core.Jaxpr  # probe the surface we need
except (ImportError, AttributeError):  # pragma: no cover - version-dependent
    from jax import core as _core  # type: ignore


def _sub_jaxprs(value: Any) -> Iterator[Any]:
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    if isinstance(value, _core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, _core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


def count_primitives(jaxpr, name: str) -> int:
    """Count eqns whose primitive is `name`, recursing into nested jaxprs.

    `jaxpr` may be a Jaxpr, a ClosedJaxpr, or the result of
    `jax.make_jaxpr(...)`. Nested call sites (pjit, scan, while, cond
    branches, shard_map bodies, ...) each contribute their own counts: two
    pjit eqns sharing one inner jaxpr count twice, mirroring how often the
    primitive appears per execution of the outer program (conditional
    branches are an over-approximation: each branch is counted).
    """
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                n += count_primitives(sub, name)
    return n


def count_in_fn(fn, name: str, *args, **kwargs) -> int:
    """Trace `fn(*args, **kwargs)` and count primitive `name` in its jaxpr."""
    return count_primitives(jax.make_jaxpr(fn)(*args, **kwargs), name)

"""Jaxpr introspection helpers: count primitives across nested call sites.

Used by the shuffle benchmarks and tests to PROVE structural claims about a
traced program — e.g. that a coalesced secure round contains exactly one
`all_to_all` and two `pallas_call` keystream launches — instead of trusting
the accounting that produced them. Counting happens on the jaxpr, not the
lowered HLO: on a single-device mesh XLA may simplify a collective away,
but the traced program is what scales to a real mesh.
"""

from __future__ import annotations

from typing import Any, Iterator

import jax

try:  # modern jax moved core under jax.extend
    from jax.extend import core as _core  # type: ignore
    _ = _core.Jaxpr  # probe the surface we need
except (ImportError, AttributeError):  # pragma: no cover - version-dependent
    from jax import core as _core  # type: ignore


def _sub_jaxprs(value: Any) -> Iterator[Any]:
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    if isinstance(value, _core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, _core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


# Cross-shard communication primitives, by jaxpr name. The superset a
# shard_map program can emit for the collectives this repo uses (psum
# lowers as psum on current jax, all_reduce on some versions); counting
# them ALL is what lets a test assert "this change introduced zero new
# collectives of any kind", not just "the one I removed is gone".
COLLECTIVE_PRIMITIVES = (
    "all_to_all", "all_gather", "psum", "all_reduce", "reduce_scatter",
    "ppermute", "pbroadcast",
)


def count_primitives(jaxpr, name: str) -> int:
    """Count eqns whose primitive is `name`, recursing into nested jaxprs.

    `jaxpr` may be a Jaxpr, a ClosedJaxpr, or the result of
    `jax.make_jaxpr(...)`. Nested call sites (pjit, scan, while, cond
    branches, shard_map bodies, ...) each contribute their own counts: two
    pjit eqns sharing one inner jaxpr count twice, mirroring how often the
    primitive appears per execution of the outer program (conditional
    branches are an over-approximation: each branch is counted).
    """
    return count_many(jaxpr, (name,))[name]


def count_many(jaxpr, names) -> dict:
    """Count several primitives in ONE jaxpr walk: {name: count}.

    Same recursion/over-approximation semantics as `count_primitives`.
    """
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    counts = dict.fromkeys(names, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                for k, n in count_many(sub, names).items():
                    counts[k] += n
    return counts


def total_eqns(jaxpr) -> int:
    """Total equation count, recursing into nested jaxprs.

    The size proxy the perf cost model (`repro/perf/model.py`) feeds its
    compile-time predictor: XLA compile time grows with the number of traced
    equations it must lower, and the calibration probe measures seconds per
    equation on a representative program. Same recursion semantics as
    `count_many` — nested call sites (pjit, scan, while, cond branches,
    shard_map bodies) each contribute their own counts.
    """
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                n += total_eqns(sub)
    return n


def collective_counts(jaxpr) -> dict:
    """Count every known collective primitive: {name: count}.

    The structural-proof helper behind the sharded-carried-state tests:
    comparing two traced programs' dicts shows exactly which collectives a
    change added or removed — e.g. that porting a state leaf to `P(axis)`
    deletes the per-round `all_gather` and introduces nothing else.
    """
    return count_many(jaxpr, COLLECTIVE_PRIMITIVES)


def count_in_fn(fn, name: str, *args, **kwargs) -> int:
    """Trace `fn(*args, **kwargs)` and count primitive `name` in its jaxpr."""
    return count_primitives(jax.make_jaxpr(fn)(*args, **kwargs), name)

"""Emit the §Dry-run + §Roofline markdown tables from reports/dryrun.json.

Usage: PYTHONPATH=src python -m repro.tools.report_md [report.json] > tables.md
"""

from __future__ import annotations

import sys

from repro.configs import get_config
from repro.tools.roofline import generate_report, param_counts


def fmt_s(x):
    return f"{x:.3g}"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.json"
    rep = generate_report(path)
    rows = rep["rows"]

    print("### Dry-run matrix (lower + compile status, peak bytes/device)\n")
    print("| arch | shape | mesh | status | compile (s) | peak GiB/dev | collectives per step |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "SKIP":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP ({r['reason'][:48]}…) | — | — | — |")
        elif r["status"] == "OK":
            coll = r.get("collectives") or {}
            cstr = ", ".join(f"{k}×{v}" for k, v in coll.items()) or "—"
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | {r['t_compile_s']} | "
                  f"{r['peak_gib']:.2f} | {cstr} |")
        else:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | — | — | — |")

    print("\n### Roofline terms (seconds per step per chip; v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)\n")
    print("| arch | shape | mesh | compute | memory | collective | dominant | MODEL/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "OK":
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(r['compute_s'])} | "
              f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | {r['dominant']} | "
              f"{r['useful_ratio']:.2f} | {r['note']} |")

    print("\n### Parameter counts\n")
    print("| arch | total params | active/token |")
    print("|---|---|---|")
    seen = set()
    for r in rows:
        if r["arch"] in seen:
            continue
        seen.add(r["arch"])
        t, a = param_counts(get_config(r["arch"]))
        print(f"| {r['arch']} | {t/1e9:.2f}B | {a/1e9:.2f}B |")


if __name__ == "__main__":
    main()

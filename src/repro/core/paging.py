"""SecurePager — the enclave-paging (EPC) analogue.

Paper §V: "one page has to be evicted from cache (and hence, encrypted),
while the one that is fetched must be decrypted and checked for integrity and
freshness (that prevents tamper and replay attacks, respectively)". The SGX
EPC limit is what produces the paper's >200 % overhead cliff at n = 1M.

This module models that mechanism explicitly: a trusted store with a byte
budget; pages evicted past the budget are ChaCha20-encrypted and MAC-tagged
with a per-page freshness counter into untrusted storage; every fetch
decrypts, verifies the tag, and checks the counter. Stats feed the paging
benchmark (Fig. 8 analogue) and the capacity-rule estimate (paper: ≈3× cache).

Cost model (for the modeled-seconds counters): a chacha20 software stream at
`CRYPTO_BYTES_PER_SEC` plus a per-page `PAGE_LATENCY_S`, calibrated against
the SGX paging cost the paper cites — these feed *modeled* overhead numbers;
wall-clock numbers in the benchmarks are real measurements of the real
cipher.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.crypto.chacha import chacha20_encrypt_bytes
from repro.crypto.mac import mac_keys_from_keystream, mac_tag_host, mac_verify_host
from repro.crypto.keys import SessionKeys

PAGE_BYTES = 4096
CRYPTO_BYTES_PER_SEC = 2.0e9  # modeled EPC encrypt/decrypt bandwidth
PAGE_LATENCY_S = 5.0e-6  # modeled per-page fault cost


class IntegrityError(RuntimeError):
    pass


class FreshnessError(RuntimeError):
    pass


@dataclass
class PagerStats:
    evictions: int = 0
    fetches: int = 0
    hits: int = 0
    bytes_encrypted: int = 0
    bytes_decrypted: int = 0
    modeled_seconds: float = 0.0
    wall_seconds: float = 0.0

    def reset(self):
        self.__init__()


class SecurePager:
    """LRU trusted store with encrypt-on-evict / verify-on-fetch semantics."""

    def __init__(self, budget_bytes: int, key: bytes, page_bytes: int = PAGE_BYTES):
        self.budget = budget_bytes
        self.page_bytes = page_bytes
        self.key = key
        self._trusted: OrderedDict[str, bytes] = OrderedDict()
        self._trusted_bytes = 0
        self._untrusted: dict[str, tuple[bytes, np.ndarray, int]] = {}
        self._fresh: dict[str, int] = {}
        self._next_ctr = 0
        self.stats = PagerStats()

    # -- internals ---------------------------------------------------------

    def _nonce(self, page_id: str) -> bytes:
        return SessionKeys.nonce("page:" + page_id)

    def _mac_keys(self, ctr: int):
        kw = np.frombuffer(self.key, dtype="<u4")
        nw = np.frombuffer(b"pager-mac---", dtype="<u4")
        return mac_keys_from_keystream(kw, nw, ctr)

    def _evict_one(self):
        page_id, data = self._trusted.popitem(last=False)
        self._trusted_bytes -= len(data)
        t0 = time.perf_counter()
        ctr = self._next_ctr
        self._next_ctr += 1
        ct = chacha20_encrypt_bytes(self.key, self._nonce(page_id), ctr, data)
        rs, ss = self._mac_keys(ctr)
        pad = (-len(ct)) % 4
        words = np.frombuffer(ct + b"\x00" * pad, dtype="<u4")
        tag = mac_tag_host(words, rs, ss)
        self._untrusted[page_id] = (ct, tag, ctr)
        self._fresh[page_id] = ctr
        self.stats.evictions += 1
        self.stats.bytes_encrypted += len(ct)
        self.stats.modeled_seconds += len(ct) / CRYPTO_BYTES_PER_SEC + PAGE_LATENCY_S
        self.stats.wall_seconds += time.perf_counter() - t0

    def _make_room(self, nbytes: int):
        while self._trusted and self._trusted_bytes + nbytes > self.budget:
            self._evict_one()

    # -- public API ----------------------------------------------------------

    def store(self, page_id: str, data: bytes):
        if page_id in self._trusted:
            self._trusted_bytes -= len(self._trusted.pop(page_id))
        self._untrusted.pop(page_id, None)
        self._make_room(len(data))
        self._trusted[page_id] = data
        self._trusted_bytes += len(data)

    def load(self, page_id: str) -> bytes:
        if page_id in self._trusted:
            self._trusted.move_to_end(page_id)
            self.stats.hits += 1
            return self._trusted[page_id]
        if page_id not in self._untrusted:
            raise KeyError(page_id)
        t0 = time.perf_counter()
        ct, tag, ctr = self._untrusted.pop(page_id)
        if self._fresh.get(page_id) != ctr:
            raise FreshnessError(f"replayed page {page_id}")  # replay protection
        rs, ss = self._mac_keys(ctr)
        pad = (-len(ct)) % 4
        words = np.frombuffer(ct + b"\x00" * pad, dtype="<u4")
        if not mac_verify_host(words, rs, ss, tag):
            raise IntegrityError(f"tampered page {page_id}")
        data = chacha20_encrypt_bytes(self.key, self._nonce(page_id), ctr, ct)
        self.stats.fetches += 1
        self.stats.bytes_decrypted += len(ct)
        self.stats.modeled_seconds += len(ct) / CRYPTO_BYTES_PER_SEC + PAGE_LATENCY_S
        self.stats.wall_seconds += time.perf_counter() - t0
        self._make_room(len(data))
        self._trusted[page_id] = data
        self._trusted_bytes += len(data)
        return data

    def tamper(self, page_id: str, byte_index: int = 0):
        """Test hook: flip a ciphertext bit in untrusted storage."""
        ct, tag, ctr = self._untrusted[page_id]
        buf = bytearray(ct)
        buf[byte_index] ^= 1
        self._untrusted[page_id] = (bytes(buf), tag, ctr)

    def replay(self, page_id: str, stale: tuple):
        """Test hook: put back a previously captured (ct, tag, ctr) blob."""
        self._untrusted[page_id] = stale

    def capture(self, page_id: str):
        return self._untrusted[page_id]

    @property
    def trusted_bytes(self) -> int:
        return self._trusted_bytes

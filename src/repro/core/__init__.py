"""The paper's primary contribution: a secure MapReduce engine in JAX.

Two execution levels implement the same model:
  * device level (`engine.py`): map/combine/shuffle/reduce inside one jitted
    shard_map program; the shuffle is a keyed `all_to_all` whose payload is
    ChaCha20-encrypted before leaving the chip ("enclave") in secure mode.
    `driver.py` fuses N such rounds (iterative jobs: k-means, sampling sort,
    streaming grep) into one dispatch via `lax.scan`, with a per-round
    keystream guaranteed by the round-index nonce layout in `shuffle.py`;
    its `run_until` adds convergence-aware termination — an on-device
    `halt_fn` masks post-convergence rounds into no-ops (no shuffle, no
    keystream) while the host grows dispatch chunks adaptively.
  * cluster level (`repro.runtime`): the paper's pub/sub-coordinated client/
    worker protocol over encrypted splits, with fault tolerance.

Plus the two SGX-specific mechanisms, adapted:
  * `secvm.py`  — code confidentiality: user logic as encrypted bytecode run
    by a generic in-graph interpreter (the Lua-VM-in-enclave analogue).
  * `paging.py` — SecurePager, the EPC paging analogue (trusted-memory
    budget; evict=>encrypt+MAC, fetch=>decrypt+verify+freshness).
"""

from repro.core.driver import (
    DEFAULT_HALT_LOOP,
    HALT_LOOP_IMPLS,
    IterativeSpec,
    RunUntilResult,
    make_iterative_runner,
    run_iterative_mapreduce,
    run_until,
)
from repro.core.engine import (
    MapReduceSpec,
    SecureShuffleConfig,
    run_mapreduce,
    run_mapreduce_until,
)

__all__ = [
    "DEFAULT_HALT_LOOP",
    "HALT_LOOP_IMPLS",
    "IterativeSpec",
    "MapReduceSpec",
    "RunUntilResult",
    "SecureShuffleConfig",
    "make_iterative_runner",
    "run_iterative_mapreduce",
    "run_mapreduce",
    "run_mapreduce_until",
    "run_until",
]

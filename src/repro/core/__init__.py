"""The paper's primary contribution: a secure MapReduce engine in JAX.

Two execution levels implement the same model:
  * device level (`engine.py`): map/combine/shuffle/reduce inside one jitted
    shard_map program; the shuffle is a keyed `all_to_all` whose payload is
    ChaCha20-encrypted before leaving the chip ("enclave") in secure mode.
  * cluster level (`repro.runtime`): the paper's pub/sub-coordinated client/
    worker protocol over encrypted splits, with fault tolerance.

Plus the two SGX-specific mechanisms, adapted:
  * `secvm.py`  — code confidentiality: user logic as encrypted bytecode run
    by a generic in-graph interpreter (the Lua-VM-in-enclave analogue).
  * `paging.py` — SecurePager, the EPC paging analogue (trusted-memory
    budget; evict=>encrypt+MAC, fetch=>decrypt+verify+freshness).
"""

from repro.core.engine import MapReduceSpec, SecureShuffleConfig, run_mapreduce

__all__ = ["MapReduceSpec", "SecureShuffleConfig", "run_mapreduce"]

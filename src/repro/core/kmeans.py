"""k-means clustering via secure MapReduce — the paper's evaluation workload.

Paper (§III, Fig. 1): step (ii) — assign each observation to the nearest
center — is the *map* function; step (iii) — recompute each center as the
centroid of its assigned points — is the *reduce* function. Mappers emit
(center_id, (point, 1)); a combiner pre-aggregates per-center partial sums
locally; the shuffle routes partials to reducer hash(c) % R; reducers average
and the client redistributes the new centers (here: a psum in which each
center row is contributed by exactly one owner).

Termination (§V): iterate until the average distance between consecutive
centers drops below a threshold; the paper uses diag/1000 of the bounding box.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import MapReduceSpec, identity_hash
from repro.core.shuffle import SecureShuffleConfig, bucket_pack, keyed_all_to_all
from repro.kernels.kmeans.ops import kmeans_assign


@dataclass(frozen=True)
class KMeansResult:
    centers: jax.Array
    n_iter: int
    center_shift: list  # avg centroid move per iteration
    inertia: float


def _kmeans_shard_step(points, weights, centers, *, axis_name, n_shards, secure, impl):
    """One k-means iteration on one shard (runs inside shard_map)."""
    k = centers.shape[0]
    # -- map + combine: fused assign + local per-center partials ("enclave")
    _, sums, counts = kmeans_assign(points, centers, weights, impl=impl)

    # -- shuffle: centroid partials to owner reducer hash(c) % R
    keys = jnp.arange(k, dtype=jnp.int32)
    bucket = keys % n_shards
    capacity = -(-k // n_shards)
    bk, bv, _ = bucket_pack(keys, bucket, {"s": sums, "c": counts}, n_shards, capacity)
    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure)

    rk = recv["k"].reshape(-1)
    rs = recv["v"]["s"].reshape(-1, sums.shape[1])
    rc = recv["v"]["c"].reshape(-1)
    valid = rk >= 0
    seg = jnp.where(valid, rk, 0)
    own_sums = jax.ops.segment_sum(jnp.where(valid[:, None], rs, 0.0), seg, num_segments=k)
    own_counts = jax.ops.segment_sum(jnp.where(valid, rc, 0.0), seg, num_segments=k)

    # -- reduce output redistribution: each center row owned by exactly one
    # reducer; psum assembles the full table on every shard (client gather).
    my = lax.axis_index(axis_name)
    mine = (jnp.arange(k) % n_shards) == my
    total_sums = lax.psum(jnp.where(mine[:, None], own_sums, 0.0), axis_name)
    total_counts = lax.psum(jnp.where(mine, own_counts, 0.0), axis_name)

    new_centers = total_sums / jnp.maximum(total_counts, 1e-9)[:, None]
    # keep empty clusters where they were (standard practice)
    new_centers = jnp.where((total_counts > 0)[:, None], new_centers, centers)
    shift = jnp.mean(jnp.linalg.norm(new_centers - centers, axis=1))
    return new_centers, shift


def make_kmeans_step(mesh: Mesh, axis_name: str = "data", secure: SecureShuffleConfig | None = None,
                     impl: str = "jnp"):
    """Build the jitted one-iteration function over `mesh`."""
    n_shards = mesh.shape[axis_name]
    body = partial(
        _kmeans_shard_step,
        axis_name=axis_name,
        n_shards=n_shards,
        secure=secure,
        impl=impl,
    )
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def kmeans_fit(
    points,
    k: int,
    mesh: Mesh,
    *,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    impl: str = "jnp",
    threshold: float | None = None,
    max_iter: int = 200,
    init_centers=None,
    init: str = "first",
    weights=None,
) -> KMeansResult:
    """Iterate to convergence. threshold=None -> paper's diag/1000 rule.

    init: "first" (paper-style arbitrary start) or "farthest" (greedy
    farthest-point, k-means++-like, robust to clumped starts).
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if init_centers is None:
        init_centers = points[:k] if init == "first" else _farthest_point_init(points, k)
    centers = jnp.asarray(init_centers, jnp.float32)

    if threshold is None:
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
        threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0  # paper §V

    step = make_kmeans_step(mesh, axis_name, secure, impl)
    shifts = []
    it = 0
    for it in range(1, max_iter + 1):
        centers, shift = step(points, weights, centers)
        shifts.append(float(shift))
        if shifts[-1] < threshold:
            break

    d2 = (
        jnp.sum(points * points, axis=1, keepdims=True)
        + jnp.sum(centers * centers, axis=1)[None, :]
        - 2.0 * points @ centers.T
    )
    inertia = float(jnp.sum(jnp.min(d2, axis=1)))
    return KMeansResult(centers=centers, n_iter=it, center_shift=shifts, inertia=inertia)


def _farthest_point_init(points, k: int):
    """Greedy farthest-point seeding (deterministic k-means++ variant)."""
    centers = [points[0]]
    d2 = jnp.sum((points - centers[0]) ** 2, axis=1)
    for _ in range(1, k):
        nxt = points[jnp.argmax(d2)]
        centers.append(nxt)
        d2 = jnp.minimum(d2, jnp.sum((points - nxt) ** 2, axis=1))
    return jnp.stack(centers)


def kmeans_step_ref(points, centers, weights=None):
    """Single-host oracle for one iteration (tests)."""
    assign, sums, counts = kmeans_assign(points, centers, weights, impl="jnp")
    new = sums / jnp.maximum(counts, 1e-9)[:, None]
    new = jnp.where((counts > 0)[:, None], new, centers)
    return new, jnp.mean(jnp.linalg.norm(new - centers, axis=1))


def generate_points(n: int, k: int, d: int = 2, seed: int = 0, spread: float = 0.05):
    """Paper §V: n random observations around k ground-truth centers in [0,1]^d."""
    rng = np.random.default_rng(seed)
    true_centers = rng.uniform(0.1, 0.9, size=(k, d))
    idx = rng.integers(0, k, size=n)
    pts = true_centers[idx] + rng.normal(scale=spread, size=(n, d))
    return pts.astype(np.float32), true_centers.astype(np.float32)

"""k-means clustering via secure MapReduce — the paper's evaluation workload.

Paper (§III, Fig. 1): step (ii) — assign each observation to the nearest
center — is the *map* function; step (iii) — recompute each center as the
centroid of its assigned points — is the *reduce* function. Mappers emit
(center_id, (point, 1)); a combiner pre-aggregates per-center partial sums
locally; the shuffle routes partials to reducer hash(c) % R; reducers average
and the client redistributes the new centers (here: a psum in which each
center row is contributed by exactly one owner).

Termination (§V): iterate until the average distance between consecutive
centers drops below a threshold; the paper uses diag/1000 of the bounding box.
The threshold test IS the job's halt predicate: `kmeans_fit` bakes it into
`IterativeSpec.halt_fn`, so the convergence decision is taken ON DEVICE by
`repro.core.driver.run_until` — the fused round loop stops paying for
map/shuffle/reduce (and stops consuming keystream, in secure mode) the moment
the average center shift crosses the threshold, and the host dispatches
adaptively growing chunks so a run converging in 7 rounds never compiles a
32-round program.

Two execution paths share the identical per-round math:
  * `make_kmeans_step` — one iteration per dispatch (the historical loop;
    kept as the oracle for equivalence tests);
  * `kmeans_fit` — convergence-aware fused rounds through
    `repro.core.driver.run_until` (halt-masked `lax.scan` under shard_map).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.driver import IterativeSpec, run_until
from repro.core.engine import MapReduceSpec, identity_hash
from repro.core.shuffle import SecureShuffleConfig, bucket_pack, keyed_all_to_all
from repro.kernels.kmeans.ops import kmeans_assign


@dataclass(frozen=True)
class KMeansResult:
    centers: jax.Array
    n_iter: int
    center_shift: list  # avg centroid move per iteration
    inertia: float
    n_dispatches: int = 0  # host->device round-trips spent on iterations
    n_rounds_dispatched: int = 0  # rounds shipped to device (>= n_iter executed)


def _assign_partials(points, weights, centers, impl):
    """Map + combine: fused assign + local per-center partials ("enclave")."""
    k = centers.shape[0]
    _, sums, counts = kmeans_assign(points, centers, weights, impl=impl)
    keys = jnp.arange(k, dtype=jnp.int32)
    return keys, {"s": sums, "c": counts}


def _reduce_centers(centers, rk, rv, valid, *, axis_name, n_shards):
    """Reduce + redistribute: own-center aggregation, psum assembly, shift."""
    k = centers.shape[0]
    rs = rv["s"]
    rc = rv["c"]
    seg = jnp.where(valid, rk, 0)
    own_sums = jax.ops.segment_sum(jnp.where(valid[:, None], rs, 0.0), seg, num_segments=k)
    own_counts = jax.ops.segment_sum(jnp.where(valid, rc, 0.0), seg, num_segments=k)

    # each center row owned by exactly one reducer; psum assembles the full
    # table on every shard (client gather) — restores state replication.
    my = lax.axis_index(axis_name)
    mine = (jnp.arange(k) % n_shards) == my
    total_sums = lax.psum(jnp.where(mine[:, None], own_sums, 0.0), axis_name)
    total_counts = lax.psum(jnp.where(mine, own_counts, 0.0), axis_name)

    new_centers = total_sums / jnp.maximum(total_counts, 1e-9)[:, None]
    # keep empty clusters where they were (standard practice)
    new_centers = jnp.where((total_counts > 0)[:, None], new_centers, centers)
    shift = jnp.mean(jnp.linalg.norm(new_centers - centers, axis=1))
    return new_centers, shift


def _kmeans_shard_step(points, weights, centers, *, axis_name, n_shards, secure, impl):
    """One k-means iteration on one shard (runs inside shard_map)."""
    k = centers.shape[0]
    keys, partials = _assign_partials(points, weights, centers, impl)
    bucket = keys % n_shards
    capacity = -(-k // n_shards)
    bk, bv, _ = bucket_pack(keys, bucket, partials, n_shards, capacity)
    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure)

    rk = recv["k"].reshape(-1)
    rv = compat.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]), recv["v"])
    return _reduce_centers(centers, rk, rv, rk >= 0, axis_name=axis_name, n_shards=n_shards)


def make_kmeans_step(mesh: Mesh, axis_name: str = "data", secure: SecureShuffleConfig | None = None,
                     impl: str = "jnp", chacha_impl: str | None = None,
                     coalesce: bool | None = None):
    """Build the jitted one-iteration function over `mesh` (oracle path).

    `impl` selects the assignment kernel; `chacha_impl` the secure-shuffle
    keystream backend and `coalesce` its wire layout (see `core/shuffle.py`).
    """
    if secure is not None:
        secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
    n_shards = mesh.shape[axis_name]
    body = partial(
        _kmeans_shard_step,
        axis_name=axis_name,
        n_shards=n_shards,
        secure=secure,
        impl=impl,
    )
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def make_kmeans_iterative_spec(k: int, n_shards: int, *, impl: str = "jnp",
                               n_rounds: int = 1, axis_name: str = "data",
                               threshold: float | None = None,
                               runtime_threshold: bool = False) -> IterativeSpec:
    """The same per-round math as `make_kmeans_step`, as a driver spec.

    Carried state = the (k, d) center table (replicated); aux per round =
    {"centers", "shift"} so convergence mid-chunk is recoverable on the host.

    `threshold` (paper §V convergence rule) installs the on-device halt
    predicate `shift < threshold` as the spec's `halt_fn` — the shift is a
    function of the replicated center table, so every shard agrees on the
    decision by construction (the driver's replicated-halt contract). The
    comparison is done in float32, matching the dtype of the on-device
    shift, so host-side reference loops must compare in float32 too to stop
    at the identical round.

    `runtime_threshold=True` is the SERVING variant: the paper's threshold
    is data-dependent (diag/1000 of the job's bounding box), so baking it
    into the traced program would force a recompile per job. Instead the
    carried state becomes {"c": centers, "thr": () f32} and the halt
    predicate reads `state["thr"]` at run time — one compiled runner then
    serves any threshold. `threshold` is ignored in this mode; weight-0
    points contribute nothing to sums/counts, so inputs padded with
    zero-weight rows up to a serving bucket fit the same program.
    """
    if runtime_threshold:
        def map_fn(state, inputs, r):
            return _assign_partials(inputs["p"], inputs["w"], state["c"], impl)

        def reduce_fn(state, rk, rv, valid, r):
            new_centers, shift = _reduce_centers(
                state["c"], rk, rv, valid, axis_name=axis_name, n_shards=n_shards
            )
            new_state = {"c": new_centers, "thr": state["thr"]}
            return new_state, {"centers": new_centers, "shift": shift}

        def halt_fn(state, aux, r):
            return aux["shift"] < state["thr"]

        return IterativeSpec(
            map_fn=map_fn,
            reduce_fn=reduce_fn,
            hash_fn=identity_hash,
            capacity=-(-k // n_shards),
            n_rounds=n_rounds,
            halt_fn=halt_fn,
            state_specs=P(),
        )

    def map_fn(centers, inputs, r):
        return _assign_partials(inputs["p"], inputs["w"], centers, impl)

    def reduce_fn(centers, rk, rv, valid, r):
        new_centers, shift = _reduce_centers(
            centers, rk, rv, valid, axis_name=axis_name, n_shards=n_shards
        )
        return new_centers, {"centers": new_centers, "shift": shift}

    halt_fn = None
    if threshold is not None:
        thr = jnp.float32(threshold)

        def halt_fn(centers, aux, r):
            return aux["shift"] < thr

    return IterativeSpec(
        map_fn=map_fn,
        reduce_fn=reduce_fn,
        hash_fn=identity_hash,
        capacity=-(-k // n_shards),
        n_rounds=n_rounds,
        halt_fn=halt_fn,
        # the center table is small and every shard's map_fn reads all of
        # it each round — replicated (P()) is the right layout, declared
        # explicitly now that the driver supports per-leaf sharding
        state_specs=P(),
    )


@dataclass
class KMeansRunnerCache:
    """Prebuilt `run_until` runner cache for `kmeans_fit` (shareable jit cache).

    Holds the iterative spec (halt threshold baked in) and the per-chunk-size
    jitted runners that `run_until` populates lazily; pass as `kmeans_fit`'s
    `runner=` to amortize the (expensive, secure-mode) XLA compiles across
    many fits with the same k/mesh/secure/impl/threshold.

    `runners` is a plain per-cache dict by default; `make_kmeans_runner`'s
    `cache=` hook replaces it with a keyed view of the process-wide serving
    `repro.serve.service.RunnerCache` (same duck-typed contract `run_until`
    accepts), so ad-hoc fits and the job service share one compile cache.
    """

    spec: IterativeSpec
    mesh: Mesh
    axis_name: str
    secure: SecureShuffleConfig | None
    chacha_impl: str | None
    loop_impl: str | None
    max_chunk: int
    threshold: float | None
    min_chunk: int = 1
    coalesce: bool | None = None
    runners: object = field(default_factory=dict)


def make_kmeans_runner(mesh: Mesh, k: int, *, axis_name: str = "data",
                       secure: SecureShuffleConfig | None = None, impl: str = "jnp",
                       rounds_per_dispatch: int = 8, threshold: float | None = None,
                       min_chunk: int = 1, chacha_impl: str | None = None,
                       loop_impl: str | None = None,
                       coalesce: bool | None = None,
                       cache=None) -> KMeansRunnerCache:
    """Prebuild the convergence-aware runner cache for `kmeans_fit`.

    `threshold` bakes the paper's §V stopping rule into the on-device
    halt_fn (None leaves halting to `kmeans_fit`'s resolved threshold at
    fit time — but then the cache cannot be reused, so pass it when known).
    `rounds_per_dispatch` caps the adaptive chunk growth (`run_until`
    max_chunk); `min_chunk` sets the first chunk's size (larger values
    amortize more rounds per dispatch up front at the cost of more masked
    no-op rounds when convergence is very fast). `chacha_impl` selects the
    secure keystream backend and `coalesce` the secure wire layout (see
    `core/shuffle.py`); `loop_impl` the halt-loop shape (`core/driver.py`).

    `cache` (a `repro.serve.service.RunnerCache`) backs the per-chunk-size
    runners with the process-wide keyed serving cache instead of a private
    dict: fits keyed by (k, mesh, secure material, impl knobs, threshold)
    then share compiled programs with the job service and each other, and
    the cache's hit/miss/evict counters see them.
    """
    spec = make_kmeans_iterative_spec(k, mesh.shape[axis_name], impl=impl,
                                      axis_name=axis_name, threshold=threshold)
    runner_cache = KMeansRunnerCache(
        spec=spec, mesh=mesh, axis_name=axis_name, secure=secure,
        chacha_impl=chacha_impl, loop_impl=loop_impl, coalesce=coalesce,
        max_chunk=max(1, rounds_per_dispatch), threshold=threshold,
        min_chunk=max(1, min_chunk),
    )
    if cache is not None:
        runner_cache.runners = cache.view(
            spec_id=("kmeans-fit", k, mesh.shape[axis_name], axis_name, impl,
                     float(threshold) if threshold is not None else None),
            mesh=mesh, axis_name=axis_name, secure=secure,
            chacha_impl=chacha_impl, loop_impl=loop_impl, coalesce=coalesce,
        )
    return runner_cache


def kmeans_fit(
    points,
    k: int,
    mesh: Mesh,
    *,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    impl: str = "jnp",
    threshold: float | None = None,
    max_iter: int = 200,
    init_centers=None,
    init: str = "first",
    weights=None,
    rounds_per_dispatch: int = 8,
    min_chunk: int = 1,
    runner: KMeansRunnerCache | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
) -> KMeansResult:
    """Iterate to convergence. threshold=None -> paper's diag/1000 rule.

    init: "first" (paper-style arbitrary start) or "farthest" (greedy
    farthest-point, k-means++-like, robust to clumped starts).

    Convergence is decided ON DEVICE: the threshold rule is the job's
    `halt_fn`, and `repro.core.driver.run_until` runs the fused round loop
    with adaptive dispatch chunking (chunks grow 1, 2, 4, ... up to
    `rounds_per_dispatch`), early-exiting the moment the average center
    shift crosses the threshold. Post-convergence rounds are never executed
    — no map, no shuffle, no keystream — and the host pays
    `KMeansResult.n_dispatches` round-trips, ~log2 of the iteration count
    plus the steady-state chunks. The global iteration count threads into
    each chunk as the driver's round_offset, keeping every secure round's
    keystream disjoint across dispatches. `runner`: a prebuilt
    `make_kmeans_runner(...)` cache to reuse its jit cache across fits
    (must match k/mesh/secure/impl/threshold; its baked-in threshold wins).
    `chacha_impl` selects the secure keystream backend and `coalesce` the
    secure wire layout (see `core/shuffle.py`); `loop_impl` the halt-loop
    shape (`core/driver.py`); all three ignored when `runner` is supplied.
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if init_centers is None:
        init_centers = points[:k] if init == "first" else _farthest_point_init(points, k)
    centers = jnp.asarray(init_centers, jnp.float32)

    if runner is not None and runner.threshold is not None:
        threshold = runner.threshold
    elif threshold is None:
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
        threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0  # paper §V

    if runner is None:
        runner = make_kmeans_runner(
            mesh, k, axis_name=axis_name, secure=secure, impl=impl,
            rounds_per_dispatch=max(1, min(rounds_per_dispatch, max_iter)),
            threshold=threshold, min_chunk=min_chunk,
            chacha_impl=chacha_impl, loop_impl=loop_impl, coalesce=coalesce,
        )
    elif runner.threshold is None:
        raise ValueError(
            "kmeans_fit runner cache was built without a threshold: pass "
            "threshold= to make_kmeans_runner so the on-device halt_fn is "
            "baked into its cached programs")
    inputs = {"p": points, "w": jnp.asarray(weights, jnp.float32)}

    res = run_until(
        runner.spec, inputs, centers, runner.mesh, runner.axis_name,
        secure=runner.secure, max_rounds=max_iter, max_chunk=runner.max_chunk,
        min_chunk=runner.min_chunk, chacha_impl=runner.chacha_impl,
        loop_impl=runner.loop_impl, coalesce=runner.coalesce,
        runners=runner.runners,
    )
    centers = jnp.asarray(res.state)
    shifts = [float(s) for s in np.asarray(res.aux["shift"])]

    d2 = (
        jnp.sum(points * points, axis=1, keepdims=True)
        + jnp.sum(centers * centers, axis=1)[None, :]
        - 2.0 * points @ centers.T
    )
    inertia = float(jnp.sum(jnp.min(d2, axis=1)))
    return KMeansResult(centers=centers, n_iter=res.rounds_executed, center_shift=shifts,
                        inertia=inertia, n_dispatches=res.n_dispatches,
                        n_rounds_dispatched=res.rounds_dispatched)


def _farthest_point_init(points, k: int):
    """Greedy farthest-point seeding (deterministic k-means++ variant)."""
    centers = [points[0]]
    d2 = jnp.sum((points - centers[0]) ** 2, axis=1)
    for _ in range(1, k):
        nxt = points[jnp.argmax(d2)]
        centers.append(nxt)
        d2 = jnp.minimum(d2, jnp.sum((points - nxt) ** 2, axis=1))
    return jnp.stack(centers)


def kmeans_step_ref(points, centers, weights=None):
    """Single-host oracle for one iteration (tests)."""
    assign, sums, counts = kmeans_assign(points, centers, weights, impl="jnp")
    new = sums / jnp.maximum(counts, 1e-9)[:, None]
    new = jnp.where((counts > 0)[:, None], new, centers)
    return new, jnp.mean(jnp.linalg.norm(new - centers, axis=1))


def generate_points(n: int, k: int, d: int = 2, seed: int = 0, spread: float = 0.05):
    """Paper §V: n random observations around k ground-truth centers in [0,1]^d."""
    rng = np.random.default_rng(seed)
    true_centers = rng.uniform(0.1, 0.9, size=(k, d))
    idx = rng.integers(0, k, size=n)
    pts = true_centers[idx] + rng.normal(scale=spread, size=(n, d))
    return pts.astype(np.float32), true_centers.astype(np.float32)

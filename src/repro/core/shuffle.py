"""Keyed shuffle: fixed-shape bucketing + (optionally encrypted) all_to_all.

The paper's mappers route each (k, v) to reducer `hash(k) % rcount` and the
framework "handles all the communication aspects". On a TPU mesh the shuffle
is a single `all_to_all` over the shuffle axis; because shapes must be static,
each mapper packs its pairs into an (R, C, ...) send buffer (R = reducers on
the axis, C = per-destination capacity) exactly like MoE capacity-factor
dispatch. Overflow is counted and surfaced, never silently lost.

Secure mode encrypts the send buffer *before* the collective and decrypts
after: ciphertext is what crosses the chip boundary ("enclave exit"), exactly
the paper's trust model for the mapper→reducer network. Counter-space layout
guarantees (key, nonce, counter) uniqueness:
  nonce word 0 = base_nonce[0] XOR source_index
  nonce word 1 = base_nonce[1] XOR round_index     (iterative driver rounds)
  ctr          = ctr0 + leaf_offset + dest_row * blocks_per_row(leaf)
so the receiver of row s (sent by source s while it sat at row `my_index` of
s's buffer) can reconstruct the exact keystream without any key exchange
beyond the session key.

The round index dimension exists for `repro.core.driver`: a multi-round job
runs many shuffles under one session key, and reusing the keystream across
rounds would be a classic two-time pad. XORing the (traced) round index into
nonce word 1 gives every round a disjoint keystream while both endpoints of
the collective can still derive it locally — the round counter is part of
the shared loop state, never transmitted.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.crypto import ctr as _ctr
from repro.crypto.chacha import chacha20_keystream_words
from repro.crypto.ctr import words_for


@dataclass(frozen=True)
class SecureShuffleConfig:
    """Session material for encrypting shuffle traffic (paper: k_shuffle)."""

    key_words: Any  # (8,) u32
    nonce_words: Any  # (3,) u32 base nonce; word 0 is XORed with source index
    counter0: int = 0


def bucket_pack(keys, bucket, values, n_buckets: int, capacity: int,
                return_positions: bool = False):
    """Pack (key, value) pairs into a fixed (R, C, ...) per-destination buffer.

    Args:
      keys:    (n,) int32; entries with key < 0 are padding (invalid).
      bucket:  (n,) int32 destination bucket in [0, n_buckets) for each item.
      values:  pytree of arrays with leading dim n.
      capacity: per-bucket slot count C.
      return_positions: also return, per input item, its flat slot index in
        [0, R*C) (or R*C when dropped/invalid) — the inverse map used by MoE
        combine to fetch each token's expert output after the return shuffle.

    Returns:
      out_keys   (R, C) int32, -1 where empty,
      out_values pytree with leading dims (R, C),
      n_dropped  () int32 — items lost to capacity overflow
      [, positions (n,) int32].
    """
    n = keys.shape[0]
    valid = keys >= 0
    b = jnp.where(valid, bucket, n_buckets)  # invalid items sort last
    order = jnp.argsort(b, stable=True)
    b_sorted = b[order]
    # position within bucket: i - first occurrence of this bucket value
    first = jnp.searchsorted(b_sorted, b_sorted, side="left")
    pos = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    in_range = (b_sorted < n_buckets) & (pos < capacity)
    dest = jnp.where(in_range, b_sorted * capacity + pos, n_buckets * capacity)
    n_dropped = jnp.sum((b_sorted < n_buckets) & (pos >= capacity)).astype(jnp.int32)

    def scatter(x_sorted, fill):
        out = jnp.full((n_buckets * capacity + 1,) + x_sorted.shape[1:], fill, x_sorted.dtype)
        out = out.at[dest].set(x_sorted)
        return out[:-1].reshape((n_buckets, capacity) + x_sorted.shape[1:])

    out_keys = scatter(keys[order], jnp.int32(-1))
    out_values = jax.tree.map(lambda v: scatter(v[order], jnp.zeros((), v.dtype)), values)
    if not return_positions:
        return out_keys, out_values, n_dropped
    positions = jnp.full((n,), n_buckets * capacity, jnp.int32).at[order].set(
        dest.astype(jnp.int32)
    )
    return out_keys, out_values, n_dropped, positions


def _row_blocks(leaf_row_shape, dtype) -> int:
    """ChaCha blocks consumed by one (C, ...) row of an (R, C, ...) leaf."""
    return -(-words_for(leaf_row_shape, dtype) // 16)


def _keystream_rows(cfg: SecureShuffleConfig, nonce_ids, ctr_rows, offset, blocks, n_words,
                    round_id=None):
    """Per-row keystream: row i uses nonce^nonce_ids[i], ctr offset+ctr_rows[i]·blocks.

    `round_id` (scalar u32, may be traced) is XORed into nonce word 1 so every
    round of an iterative job draws from a disjoint keystream.
    """
    base_nonce = jnp.asarray(cfg.nonce_words, jnp.uint32)
    if round_id is not None:
        r = jnp.asarray(round_id, jnp.uint32)
        base_nonce = base_nonce.at[1].set(base_nonce[1] ^ r)

    def one(nid, crow):
        nonce = base_nonce.at[0].set(base_nonce[0] ^ nid)
        return chacha20_keystream_words(
            cfg.key_words, nonce, offset + crow * jnp.uint32(blocks), n_words
        )

    return jax.vmap(one)(nonce_ids, ctr_rows)


def _pack_wire(tree):
    """Bitcast every (R, C, ...) leaf into an (R, n_words) u32 wire form.

    Ciphertext must never travel in a float dtype: XLA's bf16/f32 emulation
    may quiet NaN payloads in transit, corrupting bits. The wire format is
    opaque u32; shapes/dtypes are static metadata used to unpack.
    """
    leaves, treedef = jax.tree.flatten(tree)
    wires, meta = [], []
    for leaf in leaves:
        pad = _ctr.pad_for(leaf.shape[1:], leaf.dtype)
        words = jax.vmap(lambda row: _ctr._to_words(row)[0])(leaf)
        wires.append(words)
        meta.append((leaf.shape, leaf.dtype, pad))
    return wires, meta, treedef


def _unpack_wire(wires, meta, treedef):
    leaves = []
    for words, (shape, dtype, pad) in zip(wires, meta):
        row = jax.vmap(lambda w: _ctr._from_words(w, shape[1:], dtype, pad))(words)
        leaves.append(row)
    return jax.tree.unflatten(treedef, leaves)


def _crypt_wires(wires, meta, cfg, nonce_ids, ctr_rows, round_id=None):
    out = []
    offset = jnp.uint32(cfg.counter0)
    for words, (shape, dtype, _pad) in zip(wires, meta):
        r, n_words = words.shape
        blocks = _row_blocks(shape[1:], dtype)
        ks = _keystream_rows(cfg, nonce_ids, ctr_rows, offset, blocks, n_words, round_id)
        out.append(words ^ ks)
        offset = offset + jnp.uint32(blocks * r)
    return out


def keyed_all_to_all(tree, axis_name: str, secure: SecureShuffleConfig | None = None,
                     round_index=None):
    """all_to_all every (R, C, ...) leaf; row i of the result came from source i.

    In secure mode leaves are packed to u32 wire words, encrypted, exchanged,
    decrypted, and unpacked — only ciphertext crosses the inter-chip link.
    `round_index` (scalar, may be traced — e.g. a `lax.scan` carry from the
    iterative driver) selects a disjoint keystream per round; None is
    equivalent to round 0.
    """
    if secure is None:
        return jax.tree.map(lambda x: lax.all_to_all(x, axis_name, 0, 0, tiled=True), tree)

    r = jax.tree.leaves(tree)[0].shape[0]
    idx = lax.axis_index(axis_name).astype(jnp.uint32)
    wires, meta, treedef = _pack_wire(tree)

    # sender: nonce <- XOR my index; counter row <- destination row
    my_id = jnp.broadcast_to(idx, (r,))
    dest_rows = jnp.arange(r, dtype=jnp.uint32)
    wires = _crypt_wires(wires, meta, secure, my_id, dest_rows, round_index)

    wires = [lax.all_to_all(w, axis_name, 0, 0, tiled=True) for w in wires]

    # receiver: row s came from source s; at the source it sat at row my_idx
    src_ids = jnp.arange(r, dtype=jnp.uint32)
    my_rows = jnp.broadcast_to(idx, (r,))
    wires = _crypt_wires(wires, meta, secure, src_ids, my_rows, round_index)
    return _unpack_wire(wires, meta, treedef)

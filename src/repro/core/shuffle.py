"""Keyed shuffle: fixed-shape bucketing + (optionally encrypted) all_to_all.

The paper's mappers route each (k, v) to reducer `hash(k) % rcount` and the
framework "handles all the communication aspects". On a TPU mesh the shuffle
is a single `all_to_all` over the shuffle axis; because shapes must be static,
each mapper packs its pairs into an (R, C, ...) send buffer (R = reducers on
the axis, C = per-destination capacity) exactly like MoE capacity-factor
dispatch. Overflow is counted and surfaced, never silently lost.

Secure mode encrypts the send buffer *before* the collective and decrypts
after: ciphertext is what crosses the chip boundary ("enclave exit"), exactly
the paper's trust model for the mapper→reducer network. Counter-space layout
guarantees (key, nonce, counter) uniqueness:
  nonce word 0 = base_nonce[0] XOR source_index
  nonce word 1 = base_nonce[1] XOR round_index     (iterative driver rounds)
  ctr          = ctr0 + leaf_offset + dest_row * blocks_per_row(leaf)
so the receiver of row s (sent by source s while it sat at row `my_index` of
s's buffer) can reconstruct the exact keystream without any key exchange
beyond the session key.

The round index dimension exists for `repro.core.driver`: a multi-round job
runs many shuffles under one session key, and reusing the keystream across
rounds would be a classic two-time pad. XORing the (traced) round index into
nonce word 1 gives every round a disjoint keystream while both endpoints of
the collective can still derive it locally — the round counter is part of
the shared loop state, never transmitted.

Coalesced wire layout (default)
-------------------------------
The whole pytree crosses the boundary as ONE (R, payload_words) u32 wire:
every leaf's word rows are concatenated PACKED on the word axis at STATIC
per-leaf offsets — no block-alignment pad travels — so one keystream launch
encrypts/decrypts the buffer and exactly one `lax.all_to_all` moves it, per
secure round, regardless of tree width (vs one collective per leaf and two
launches per leaf on the per-leaf path). For a 3-leaf tree
{k:(R,C) i32, s:(R,C,d) f32, c:(R,C) f32}:

    wire row i:  |<- leaf k ->|<--- leaf s --->|<- leaf c ->|
    words        [    Wk    ]  [      Ws     ]  [    Wc    ]
    word offset  0             Wk               Wk+Ws
    block ctr    c0+i·Bk+b     c0+R·Bk+i·Bs+b   c0+R·(Bk+Bs)+i·Bc+b

where W* = words_for(leaf row), B* = ceil(W*/16), b the intra-leaf block
index, and c0 = counter0. KEYSTREAM, unlike payload, is derived in the
block-ALIGNED virtual layout: one launch computes all 16·ΣB* words per row
(ctr vectors below), and each leaf's first W* words are sliced out at its
aligned offset 16·Σ preceding B* and XORed onto the packed segment. Each
leaf region therefore keeps the EXACT per-leaf (key, nonce, counter)
assignment (leaf_offset + row·blocks_per_row + b): the coalesced and
per-leaf layouts draw bit-identical keystream per leaf region — they are
cross-checkable ciphertexts, and the per-leaf path is retained as the
differential oracle (`SecureShuffleConfig.coalesce=False`). Discarded
keystream tail words (blocks whose payload ends mid-block) were derived
and discarded by the per-leaf path too, and CTR keystream words leak
nothing about other words of the same or any other block. The wire carries
ZERO pad bytes (`record_wire_bytes` reports `pad_bytes == 0`); the only
residual padding anywhere is `crypto/ctr.words_for`'s sub-word packing of
narrow dtypes inside W* itself.

Plaintext (`secure=None`) shuffles default to the SAME packed single-wire
topology minus the crypt — one `lax.all_to_all` per round, zero keystream
launches — so a secure-vs-plain jaxpr diff isolates the cryptography, not
the wire shape; `resolve_coalesce(False)` restores the historical
per-leaf collectives as the differential oracle.

The per-(row, block) counter of the coalesced wire is not a single linear
ramp, so `kernels/chacha20.chacha20_xor_rows_coalesced` takes vector
per-block counter bases: ctr[i, j] = ctr_base[j] + ctr_rowmul[j] · row_ctr[i]
with ctr_base = leaf counter offset + intra-leaf block index and ctr_rowmul
= the leaf's blocks-per-row stride.

`SecureShuffleConfig.coalesce` selects the layout: True | False | 'auto'
(the default — reads $REPRO_SHUFFLE_COALESCE, else True). Like `impl`, the
choice is read at trace time and an explicit bool always wins over the
environment.

Keystream implementation selection
----------------------------------
Two interchangeable backends compute the per-row keystream; the counter-space
layout above is IDENTICAL under both, so they are bit-exact by construction
(and proven so by `tests/test_shuffle_impls.py`):

  * ``pallas`` (default) — `repro.kernels.chacha20.chacha20_xor_rows` /
    `chacha20_xor_rows_coalesced`: the whole wire buffer in one Pallas
    launch gridded over rows × 128-wide block-LANE tiles (blocks on the
    lane dim, so the compiled TPU lowering fills every VREG lane).
    Interpret mode off-TPU keeps XLA from constant-folding the 20-round
    ARX chain, which is what made secure-mode compiles take ~40-110s per
    config on the historical path.
  * ``jnp`` — the vmapped pure-jnp ChaCha, kept as the differential-testing
    oracle.

Selection: `SecureShuffleConfig.impl` ('auto' | 'pallas' |
'pallas-interpret' | 'jnp'). 'auto' resolves to the `REPRO_CHACHA_IMPL`
environment variable when set, else 'pallas'; an explicit non-'auto' value
always wins over the environment. The choice is read at trace time — an env
flip after a runner is jitted does not retrace it. If the Pallas frontend is
unimportable on this platform, 'auto'/'pallas' silently fall back to 'jnp'
(same bits, slower compile).
"""

from __future__ import annotations

import os
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.crypto import ctr as _ctr
from repro.crypto.chacha import chacha20_block_words, chacha20_keystream_words
from repro.crypto.ctr import words_for

try:  # the Pallas frontend may be absent on exotic platforms
    from repro.kernels.chacha20.ops import (
        chacha20_xor_rows,
        chacha20_xor_rows_coalesced,
        make_state0,
    )

    _HAVE_PALLAS = True
except ImportError:  # pragma: no cover - exercised only without Pallas
    chacha20_xor_rows = chacha20_xor_rows_coalesced = make_state0 = None
    _HAVE_PALLAS = False

CHACHA_IMPL_ENV = "REPRO_CHACHA_IMPL"
_VALID_IMPLS = ("auto", "pallas", "pallas-interpret", "jnp")

COALESCE_ENV = "REPRO_SHUFFLE_COALESCE"
_COALESCE_TRUE = ("1", "true", "yes", "on")
_COALESCE_FALSE = ("0", "false", "no", "off")


def _model_recommendation(knob: str, **ctx):
    """Ask the calibrated cost model for an `auto` knob value.

    Returns None when no calibration is active ($REPRO_CALIBRATION unset and
    nothing set via `repro.perf.model.set_active_model`), which keeps every
    `auto` resolver bit-for-bit on its historical default. Imported lazily:
    `repro.perf.model` traces programs through this module, and most resolver
    calls never need it.
    """
    from repro.perf.model import recommendation

    return recommendation(knob, **ctx)


def resolve_coalesce(coalesce="auto") -> bool:
    """Resolve a coalesce selector to a concrete bool (read at trace time).

    An explicit bool always wins; 'auto'/None defers to
    $REPRO_SHUFFLE_COALESCE, then to the calibrated cost model when one is
    active (`repro/perf/model.py`), then to the measured default True.
    Mirrors `resolve_chacha_impl`, including blaming the environment when
    its value is unparseable.
    """
    if isinstance(coalesce, (bool, np.bool_)):
        return bool(coalesce)
    if coalesce in (None, "auto"):
        env_val = os.environ.get(COALESCE_ENV)
        if env_val is None:
            rec = _model_recommendation("coalesce")
            return True if rec is None else bool(rec)
        val = env_val.strip().lower()
        if val in _COALESCE_TRUE:
            return True
        if val in _COALESCE_FALSE:
            return False
        raise ValueError(
            f"invalid ${COALESCE_ENV}={env_val!r} in the environment: "
            f"must be one of {_COALESCE_TRUE + _COALESCE_FALSE} "
            f"(unset ${COALESCE_ENV} to use the default coalesced wire)")
    raise ValueError(
        f"coalesce must be a bool or 'auto', got {coalesce!r}")


def resolve_chacha_impl(impl: str = "auto") -> tuple[str, bool]:
    """Resolve an impl selector to concrete (impl, interpret) kernel args.

    'auto' defers to $REPRO_CHACHA_IMPL, then to the calibrated cost model
    when one is active (the impl whose probed us/block wins;
    `repro/perf/model.py`), then to the measured default 'pallas'; explicit
    values win over the environment. 'pallas-interpret' forces interpret
    mode even on a backend with a compiled Pallas lowering; plain 'pallas'
    interprets only off-TPU. Falls back to 'jnp' when Pallas is unimportable.
    """
    from_env = False
    if impl in (None, "auto"):
        env_val = os.environ.get(CHACHA_IMPL_ENV)
        if env_val is None:
            rec = _model_recommendation("chacha_impl")
            impl = "pallas" if rec is None else rec
        else:
            impl, from_env = env_val, True
    if impl not in _VALID_IMPLS or impl == "auto":
        if from_env:
            raise ValueError(
                f"invalid ${CHACHA_IMPL_ENV}={impl!r} in the environment: "
                f"chacha impl must be one of {_VALID_IMPLS[1:]} "
                f"(unset ${CHACHA_IMPL_ENV} to use the default 'pallas')")
        raise ValueError(
            f"chacha impl must be one of {_VALID_IMPLS[1:]}, got {impl!r}")
    if impl == "jnp" or not _HAVE_PALLAS:
        return "jnp", True
    if impl == "pallas-interpret":
        return "pallas", True
    return "pallas", jax.default_backend() != "tpu"


@dataclass(frozen=True)
class SecureShuffleConfig:
    """Session material for encrypting shuffle traffic (paper: k_shuffle).

    `impl` picks the keystream backend (module docstring): 'auto' (env-
    overridable, default 'pallas'), 'pallas', 'pallas-interpret', or 'jnp'.
    `coalesce` picks the wire layout (module docstring): True — the whole
    pytree as one wire buffer, one keystream launch each side of ONE
    all_to_all per round — False — the per-leaf differential oracle — or
    'auto' (env-overridable via $REPRO_SHUFFLE_COALESCE, default True).
    """

    key_words: Any  # (8,) u32
    nonce_words: Any  # (3,) u32 base nonce; word 0 is XORed with source index
    counter0: int = 0
    impl: str = "auto"
    coalesce: Any = "auto"  # bool | 'auto'

    def with_impl(self, impl: str | None) -> "SecureShuffleConfig":
        """Copy with a different keystream impl (None keeps the current one)."""
        if impl is None or impl == self.impl:
            return self
        from dataclasses import replace

        return replace(self, impl=impl)

    def with_coalesce(self, coalesce) -> "SecureShuffleConfig":
        """Copy with a different wire layout (None keeps the current one)."""
        if coalesce is None or coalesce == self.coalesce:
            return self
        from dataclasses import replace

        return replace(self, coalesce=coalesce)


def bucket_pack(keys, bucket, values, n_buckets: int, capacity: int,
                return_positions: bool = False):
    """Pack (key, value) pairs into a fixed (R, C, ...) per-destination buffer.

    Args:
      keys:    (n,) int32; entries with key < 0 are padding (invalid).
      bucket:  (n,) int32 destination bucket in [0, n_buckets) for each item.
      values:  pytree of arrays with leading dim n.
      capacity: per-bucket slot count C.
      return_positions: also return, per input item, its flat slot index in
        [0, R*C) (or R*C when dropped/invalid) — the inverse map used by MoE
        combine to fetch each token's expert output after the return shuffle.

    Returns:
      out_keys   (R, C) int32, -1 where empty,
      out_values pytree with leading dims (R, C),
      n_dropped  () int32 — items lost to capacity overflow
      [, positions (n,) int32].
    """
    n = keys.shape[0]
    valid = keys >= 0
    b = jnp.where(valid, bucket, n_buckets)  # invalid items sort last
    order = jnp.argsort(b, stable=True)
    b_sorted = b[order]
    # position within bucket: i - first occurrence of this bucket value
    first = jnp.searchsorted(b_sorted, b_sorted, side="left")
    pos = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    in_range = (b_sorted < n_buckets) & (pos < capacity)
    dest = jnp.where(in_range, b_sorted * capacity + pos, n_buckets * capacity)
    n_dropped = jnp.sum((b_sorted < n_buckets) & (pos >= capacity)).astype(jnp.int32)

    def scatter(x_sorted, fill):
        if any(d == 0 for d in x_sorted.shape[1:]):
            # Zero-size trailing dims (e.g. a (n, 0) per-item leaf): the
            # n_buckets*capacity+1 overflow-slot scatter below degenerates —
            # there are no elements to place, only shapes to produce — so
            # return the empty fixed-shape buffer directly instead of
            # emitting a 0-element XLA scatter.
            return jnp.zeros((n_buckets, capacity) + x_sorted.shape[1:], x_sorted.dtype)
        out = jnp.full((n_buckets * capacity + 1,) + x_sorted.shape[1:], fill, x_sorted.dtype)
        out = out.at[dest].set(x_sorted)
        return out[:-1].reshape((n_buckets, capacity) + x_sorted.shape[1:])

    out_keys = scatter(keys[order], jnp.int32(-1))
    out_values = jax.tree.map(lambda v: scatter(v[order], jnp.zeros((), v.dtype)), values)
    if not return_positions:
        return out_keys, out_values, n_dropped
    positions = jnp.full((n,), n_buckets * capacity, jnp.int32).at[order].set(
        dest.astype(jnp.int32)
    )
    return out_keys, out_values, n_dropped, positions


def _row_blocks(leaf_row_shape, dtype) -> int:
    """ChaCha blocks consumed by one (C, ...) row of an (R, C, ...) leaf."""
    return -(-words_for(leaf_row_shape, dtype) // 16)


def _round_nonce(cfg: SecureShuffleConfig, round_id):
    """Base nonce for this round: word 1 ^= round index (may be traced)."""
    base_nonce = jnp.asarray(cfg.nonce_words, jnp.uint32)
    if round_id is not None:
        r = jnp.asarray(round_id, jnp.uint32)
        base_nonce = base_nonce.at[1].set(base_nonce[1] ^ r)
    return base_nonce


def _crypt_rows(cfg: SecureShuffleConfig, words, nonce_ids, ctr_starts, round_id):
    """XOR an (R, n_words) wire buffer with the per-row keystream.

    Row i uses nonce word 0 XOR nonce_ids[i] and absolute block counter start
    ctr_starts[i]; nonce word 1 carries the round index. Dispatches to the
    backend selected by `cfg.impl` via `repro.kernels.chacha20`; when the
    Pallas frontend is unimportable, a local vmapped jnp path (bit-identical
    by construction) keeps secure mode working.
    """
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_starts = jnp.asarray(ctr_starts, jnp.uint32)
    base_nonce = _round_nonce(cfg, round_id)
    if _HAVE_PALLAS:
        impl, interpret = resolve_chacha_impl(cfg.impl)
        state0 = make_state0(cfg.key_words, base_nonce, 0)
        return chacha20_xor_rows(words, state0, nonce_ids, ctr_starts,
                                 impl=impl, interpret=interpret)

    n_words = words.shape[1]  # pragma: no cover - exercised only without Pallas

    def one(row, nid, ctr0):
        nonce = base_nonce.at[0].set(base_nonce[0] ^ nid)
        return row ^ chacha20_keystream_words(cfg.key_words, nonce, ctr0, n_words)

    return jax.vmap(one)(words, nonce_ids, ctr_starts)


def _keystream_rows(cfg: SecureShuffleConfig, nonce_ids, ctr_rows, offset, blocks, n_words,
                    round_id=None):
    """Per-row keystream: row i uses nonce^nonce_ids[i], ctr offset+ctr_rows[i]·blocks.

    `round_id` (scalar u32, may be traced) is XORed into nonce word 1 so every
    round of an iterative job draws from a disjoint keystream. Routed through
    the impl selected by `cfg.impl` (keystream = XOR with zeros).
    """
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_starts = jnp.uint32(offset) + jnp.asarray(ctr_rows, jnp.uint32) * jnp.uint32(blocks)
    zeros = jnp.zeros((nonce_ids.shape[0], n_words), jnp.uint32)
    return _crypt_rows(cfg, zeros, nonce_ids, ctr_starts, round_id)


def _pack_wire(tree):
    """Bitcast every (R, C, ...) leaf into an (R, n_words) u32 wire form.

    Ciphertext must never travel in a float dtype: XLA's bf16/f32 emulation
    may quiet NaN payloads in transit, corrupting bits. The wire format is
    opaque u32; shapes/dtypes are static metadata used to unpack.
    """
    leaves, treedef = jax.tree.flatten(tree)
    wires, meta = [], []
    for leaf in leaves:
        pad = _ctr.pad_for(leaf.shape[1:], leaf.dtype)
        words = jax.vmap(lambda row: _ctr._to_words(row)[0])(leaf)
        wires.append(words)
        meta.append((leaf.shape, leaf.dtype, pad))
    return wires, meta, treedef


def _unpack_wire(wires, meta, treedef):
    leaves = []
    for words, (shape, dtype, pad) in zip(wires, meta):
        row = jax.vmap(lambda w: _ctr._from_words(w, shape[1:], dtype, pad))(words)
        leaves.append(row)
    return jax.tree.unflatten(treedef, leaves)


def _crypt_wires(wires, meta, cfg, nonce_ids, ctr_rows, round_id=None):
    out = []
    ctr_rows = jnp.asarray(ctr_rows, jnp.uint32)
    offset = jnp.uint32(cfg.counter0)
    for words, (shape, dtype, _pad) in zip(wires, meta):
        r, n_words = words.shape
        blocks = _row_blocks(shape[1:], dtype)
        ctr_starts = offset + ctr_rows * jnp.uint32(blocks)
        out.append(_crypt_rows(cfg, words, nonce_ids, ctr_starts, round_id))
        offset = offset + jnp.uint32(blocks * r)
    return out


@dataclass(frozen=True)
class _WireLayout:
    """Static unpack/counter metadata for a coalesced (R, payload_words) wire.

    leaves:      per-leaf (shape, dtype, narrow-pad, word_start, n_words,
                 blocks, ks_start) tuples — word_start is the leaf segment's
                 offset on the PACKED wire's word axis (no alignment pad);
                 ks_start = 16·Σ preceding blocks is the segment's offset in
                 the block-ALIGNED keystream layout the crypt derives.
    ctr_base:    (total_blocks,) u32 — per-block counter base: the leaf's
                 counter-space offset (Σ preceding blocks·R, matching the
                 per-leaf path) + the intra-leaf block index. cfg.counter0
                 is added at crypt time.
    ctr_rowmul:  (total_blocks,) u32 — per-block row stride: the owning
                 leaf's blocks-per-row.
    """

    leaves: tuple
    ctr_base: Any  # (total_blocks,) np.uint32
    ctr_rowmul: Any  # (total_blocks,) np.uint32
    total_blocks: int

    @property
    def total_words(self) -> int:
        """Words of the block-aligned KEYSTREAM layout (≥ payload_words)."""
        return self.total_blocks * 16

    @property
    def payload_words(self) -> int:
        """Words of the packed wire — exactly what crosses the link."""
        return sum(m[4] for m in self.leaves)


def _pack_wire_coalesced(tree):
    """Bitcast + concatenate the whole pytree into ONE packed u32 wire.

    Leaf word rows are concatenated back-to-back on the word axis at static
    offsets — leaf tails share blocks with the next leaf's head on the wire,
    so ZERO block-alignment pad travels. The counter space stays the
    block-aligned per-leaf assignment (the crypt slices each leaf's words
    out of an aligned keystream; `_WireLayout`). Returns (wire, layout,
    treedef).
    """
    leaves, treedef = jax.tree.flatten(tree)
    r = leaves[0].shape[0]
    segs, meta = [], []
    word_off = 0  # PACKED wire word offset
    ctr_off = 0  # counter-space offset: Σ preceding blocks · R
    ks_off = 0  # aligned-keystream word offset: 16 · Σ preceding blocks
    base_parts, mul_parts = [], []
    for leaf in leaves:
        pad = _ctr.pad_for(leaf.shape[1:], leaf.dtype)
        words = jax.vmap(lambda row: _ctr._to_words(row)[0])(leaf)
        n_words = words.shape[1]
        blocks = -(-n_words // 16)
        segs.append(words)
        meta.append((leaf.shape, leaf.dtype, pad, word_off, n_words, blocks, ks_off))
        base_parts.append(np.uint32(ctr_off) + np.arange(blocks, dtype=np.uint32))
        mul_parts.append(np.full((blocks,), blocks, np.uint32))
        word_off += n_words
        ctr_off += blocks * r
        ks_off += blocks * 16
    wire = (jnp.concatenate(segs, axis=1) if segs
            else jnp.zeros((r, 0), jnp.uint32))
    layout = _WireLayout(
        leaves=tuple(meta),
        ctr_base=(np.concatenate(base_parts) if base_parts
                  else np.zeros((0,), np.uint32)),
        ctr_rowmul=(np.concatenate(mul_parts) if mul_parts
                    else np.zeros((0,), np.uint32)),
        total_blocks=ks_off // 16,
    )
    return wire, layout, treedef


def _unpack_wire_coalesced(wire, layout: _WireLayout, treedef):
    leaves = []
    for shape, dtype, pad, word_start, n_words, _blocks, _ks in layout.leaves:
        words = lax.slice_in_dim(wire, word_start, word_start + n_words, axis=1)
        leaves.append(
            jax.vmap(lambda w: _ctr._from_words(w, shape[1:], dtype, pad))(words))
    return jax.tree.unflatten(treedef, leaves)


def _packed_keystream(ks_aligned, layout: _WireLayout):
    """Slice the packed wire's keystream out of the block-aligned keystream.

    `ks_aligned` is (R, 16·total_blocks): each leaf's first n_words at its
    aligned ks_start offset, concatenated, give the (R, payload_words)
    keystream whose XOR with the packed wire reproduces the per-leaf
    ciphertext bit-for-bit; the skipped tail words are discarded exactly as
    the per-leaf path discards them.
    """
    segs = [lax.slice_in_dim(ks_aligned, m[6], m[6] + m[4], axis=1)
            for m in layout.leaves]
    return jnp.concatenate(segs, axis=1) if segs else ks_aligned[:, :0]


def _crypt_wire_coalesced(wire, layout: _WireLayout, cfg, nonce_ids, ctr_rows,
                          round_id=None):
    """XOR the packed coalesced wire with its keystream — ONE launch.

    The keystream is derived in the block-aligned layout (XOR with zeros):
    block j of row i uses counter counter0 + ctr_base[j] + ctr_rowmul[j] ·
    ctr_rows[i] and nonce word 0 XOR nonce_ids[i] — bit-identical per leaf
    region to what `_crypt_wires` derives on the per-leaf path — then each
    leaf's payload words are sliced out (`_packed_keystream`) and XORed onto
    the packed wire, so no pad words travel.
    """
    if layout.total_blocks == 0:
        return wire
    nonce_ids = jnp.asarray(nonce_ids, jnp.uint32)
    ctr_rows = jnp.asarray(ctr_rows, jnp.uint32)
    ctr_base = jnp.uint32(cfg.counter0) + jnp.asarray(layout.ctr_base, jnp.uint32)
    ctr_rowmul = jnp.asarray(layout.ctr_rowmul, jnp.uint32)
    base_nonce = _round_nonce(cfg, round_id)
    zeros = jnp.zeros((wire.shape[0], layout.total_words), jnp.uint32)
    if _HAVE_PALLAS:
        impl, interpret = resolve_chacha_impl(cfg.impl)
        state0 = make_state0(cfg.key_words, base_nonce, 0)
        ks = chacha20_xor_rows_coalesced(zeros, state0, nonce_ids, ctr_rows,
                                         ctr_base, ctr_rowmul,
                                         impl=impl, interpret=interpret)
    else:  # pragma: no cover - exercised only without Pallas
        key_words = jnp.asarray(cfg.key_words, jnp.uint32)

        def one(nid, rc):
            nonce = base_nonce.at[0].set(base_nonce[0] ^ nid)
            counters = ctr_base + ctr_rowmul * rc
            return chacha20_block_words(key_words, counters, nonce).reshape(-1)

        ks = jax.vmap(one)(nonce_ids, ctr_rows)
    return wire ^ _packed_keystream(ks, layout)


class _WireAccounting:
    """Trace-time shuffle byte counter (see `record_wire_bytes`).

    Re-entrant by construction: active `record_wire_bytes` contexts form a
    STACK of independent record sinks (every traced shuffle appends to all
    of them), suppression is a nesting counter, and the job attribution of
    a record comes from the innermost `tagged(job_id)` context — so two
    interleaved `run_until` jobs (the serving path: chunk dispatches of
    concurrent jobs alternate on one host thread, each holding its own
    open recording context across its generator's suspensions) neither
    clobber each other's record lists nor mis-attribute records. Sinks are
    removed by IDENTITY on context exit, so out-of-LIFO-order exits — the
    norm for generator-held contexts — are safe.
    """

    def __init__(self):
        self._sinks: list[list] = []
        self._tags: list = []
        self._suppress = 0

    @property
    def enabled(self) -> bool:
        return bool(self._sinks) and self._suppress == 0

    def note(self, *, secure: bool, nbytes: int, n_leaves: int, halted: bool = False,
             coalesced: bool = False, pad_bytes: int = 0,
             per_leaf: list | None = None, collectives: int = 0,
             keystream_launches: int = 0, keystream_blocks: int = 0):
        """Append one record per traced `keyed_all_to_all` to every sink.

        bytes:              payload bytes — raw leaf bytes in plaintext
                            mode, packed u32 payload words in secure mode;
                            the quantity `bench_data_volume` compares to
                            prove zero CTR ciphertext expansion.
        wire_bytes:         bytes actually crossing the inter-chip link =
                            bytes + pad_bytes (the coalesced wire's ≤15-word
                            per-leaf block-alignment pad; 0 otherwise).
        per_leaf:           per-leaf payload byte breakdown, in pytree leaf
                            order, so the zero-expansion claim is auditable
                            LEAF BY LEAF even when the wire is coalesced.
        collectives:        all_to_all ops this shuffle traces per round.
        keystream_launches: keystream derivations (encrypt + decrypt) this
                            shuffle traces per round; 0 in plaintext mode.
        keystream_blocks:   total ChaCha20 blocks derived per round, summed
                            across launches (UNPADDED — kernel lane-tile
                            padding is an impl detail the cost model applies
                            itself); 0 in plaintext mode.
        job:                innermost `tagged` job id, or None — lets a
                            shared sink split interleaved jobs' records.
        """
        if not self.enabled:
            return
        rec = {"secure": secure, "bytes": nbytes, "leaves": n_leaves,
               "halted": halted, "coalesced": coalesced,
               "wire_bytes": nbytes + pad_bytes, "pad_bytes": pad_bytes,
               "per_leaf": list(per_leaf or []), "collectives": collectives,
               "keystream_launches": keystream_launches,
               "keystream_blocks": keystream_blocks,
               "job": self._tags[-1] if self._tags else None}
        for sink in self._sinks:
            sink.append(dict(rec))

    def note_halted_round(self, secure: bool = True):
        """Record the halted-round passthrough: ZERO bytes cross the wire.

        Called while tracing the skip branch of the driver's halt-masked
        round loop — the branch contains no all_to_all and no keystream
        derivation, so the bytes a halted round contributes are zero by
        construction, and the record makes that auditable from benchmarks.
        """
        self.note(secure=secure, nbytes=0, n_leaves=0, halted=True)

    @contextmanager
    def suppressed(self):
        """Context: disable recording (abstract eval_shape passes would
        otherwise double-count a shuffle the driver only traces for shapes).
        Nestable — a counter, not a flag, so an inner suppression cannot
        un-suppress an outer one."""
        self._suppress += 1
        try:
            yield
        finally:
            self._suppress -= 1

    @contextmanager
    def tagged(self, job_id):
        """Context: attribute records traced inside to `job_id`.

        The driver wraps each chunk dispatch of a tagged job in this, so a
        sink shared by interleaved jobs can be split by the records' "job"
        field. None is a no-op (records keep the enclosing tag, if any).
        """
        if job_id is None:
            yield
            return
        self._tags.append(job_id)
        try:
            yield
        finally:
            self._tags.remove(job_id)


wire_accounting = _WireAccounting()


class record_wire_bytes:
    """Context manager: record per-shuffle wire bytes at TRACE time.

    Every `keyed_all_to_all` traced inside the block appends one record with
    the exact byte count that crosses the inter-chip link per shard — raw
    leaf bytes in plaintext mode, packed u32 wire words in secure mode.
    Shapes are static, so trace-time accounting is exact; a shuffle inside
    `lax.scan` (the iterative driver) traces once and records ONE round's
    bytes. Used by `benchmarks/bench_data_volume.py` to prove CTR ciphertext
    expansion is zero.

    RE-ENTRANT: contexts nest (each gets its own record list; a shuffle
    traced under several open contexts lands in all of them) and may exit
    in any order — each `__exit__` removes only its own sink — so
    interleaved `run_until` jobs that each hold a context open across
    host-dispatch turns cannot corrupt one another's accounting. Records
    carry a "job" field from the innermost `wire_accounting.tagged(job_id)`
    context (None untagged) to split a shared sink by job.
    """

    def __init__(self):
        self.records: list[dict] = []

    def __enter__(self):
        self.records = []
        wire_accounting._sinks.append(self.records)
        return self.records

    def __exit__(self, *exc):
        # remove by IDENTITY, wherever it sits: interleaved contexts exit
        # out of stack order
        for i, sink in enumerate(wire_accounting._sinks):
            if sink is self.records:
                del wire_accounting._sinks[i]
                break
        return False


def keyed_all_to_all(tree, axis_name: str, secure: SecureShuffleConfig | None = None,
                     round_index=None, coalesce=None):
    """all_to_all every (R, C, ...) leaf; row i of the result came from source i.

    In secure mode leaves are packed to u32 wire words, encrypted, exchanged,
    decrypted, and unpacked — only ciphertext crosses the inter-chip link.
    With the default coalesced layout (`secure.coalesce`, module docstring)
    the whole pytree travels as ONE packed wire buffer (zero pad bytes): one
    keystream launch each side of exactly one `lax.all_to_all`, regardless
    of tree width; the per-leaf layout (one collective and two launches per
    leaf) is kept as the differential oracle. Plaintext mode uses the SAME
    wire topology minus the crypt, selected by `coalesce` (True | False |
    None → 'auto', i.e. $REPRO_SHUFFLE_COALESCE, default True; in secure
    mode the config's own `secure.coalesce` governs and `coalesce` is
    ignored). `round_index` (scalar, may be traced — e.g. a `lax.scan`
    carry from the iterative driver) selects a disjoint keystream per
    round; None is equivalent to round 0.
    """
    if secure is None:
        leaves = jax.tree.leaves(tree)
        raw_bytes = [l.size * l.dtype.itemsize for l in leaves]
        if resolve_coalesce("auto" if coalesce is None else coalesce):
            wire, layout, treedef = _pack_wire_coalesced(tree)
            r = wire.shape[0]
            wire_accounting.note(
                secure=False,
                nbytes=layout.payload_words * r * 4,
                n_leaves=len(layout.leaves),
                coalesced=True,
                pad_bytes=0,
                per_leaf=[m[4] * r * 4 for m in layout.leaves],
                collectives=1,
            )
            wire = lax.all_to_all(wire, axis_name, 0, 0, tiled=True)
            return _unpack_wire_coalesced(wire, layout, treedef)
        wire_accounting.note(
            secure=False,
            nbytes=sum(raw_bytes),
            n_leaves=len(leaves),
            per_leaf=raw_bytes,
            collectives=len(leaves),
        )
        return jax.tree.map(lambda x: lax.all_to_all(x, axis_name, 0, 0, tiled=True), tree)

    r = jax.tree.leaves(tree)[0].shape[0]
    idx = lax.axis_index(axis_name).astype(jnp.uint32)

    # sender: nonce <- XOR my index; counter row <- destination row
    my_id = jnp.broadcast_to(idx, (r,))
    dest_rows = jnp.arange(r, dtype=jnp.uint32)
    # receiver: row s came from source s; at the source it sat at row my_idx
    src_ids = jnp.arange(r, dtype=jnp.uint32)
    my_rows = jnp.broadcast_to(idx, (r,))

    if resolve_coalesce(secure.coalesce):
        wire, layout, treedef = _pack_wire_coalesced(tree)
        per_leaf = [m[4] * r * 4 for m in layout.leaves]
        wire_accounting.note(
            secure=True,
            nbytes=sum(per_leaf),
            n_leaves=len(layout.leaves),
            coalesced=True,
            pad_bytes=wire.shape[1] * r * 4 - sum(per_leaf),  # 0: packed wire
            per_leaf=per_leaf,
            collectives=1,
            keystream_launches=2,
            keystream_blocks=2 * r * layout.total_blocks,
        )
        wire = _crypt_wire_coalesced(wire, layout, secure, my_id, dest_rows,
                                     round_index)
        wire = lax.all_to_all(wire, axis_name, 0, 0, tiled=True)
        wire = _crypt_wire_coalesced(wire, layout, secure, src_ids, my_rows,
                                     round_index)
        return _unpack_wire_coalesced(wire, layout, treedef)

    wires, meta, treedef = _pack_wire(tree)
    wire_accounting.note(
        secure=True,
        nbytes=sum(w.size * 4 for w in wires),
        n_leaves=len(wires),
        per_leaf=[w.size * 4 for w in wires],
        collectives=len(wires),
        keystream_launches=2 * len(wires),
        keystream_blocks=2 * sum(w.shape[0] * -(-w.shape[1] // 16) for w in wires),
    )

    wires = _crypt_wires(wires, meta, secure, my_id, dest_rows, round_index)

    wires = [lax.all_to_all(w, axis_name, 0, 0, tiled=True) for w in wires]

    wires = _crypt_wires(wires, meta, secure, src_ids, my_rows, round_index)
    return _unpack_wire(wires, meta, treedef)

"""TeraSort-style sampling sort on the iterative secure MapReduce driver.

Classic MapReduce sort: pick R-1 splitters, range-partition every record to
reducer i iff splitter[i-1] <= v < splitter[i], each reducer sorts its range
locally; the concatenation of reducer outputs is globally sorted. The hard
part is *choosing* the splitters — TeraSort samples the input first.

Here the sampling pass and the sort pass are rounds of ONE convergence-aware
`run_until` job: every round range-partitions by the *current* splitter
table (carried state), reducers sort what they received and count their
load, and the reduce step refines the splitters toward equi-depth by
inverting the piecewise-linear CDF observed on the round's bucket counts.
Round 0 with uniform splitters is the "sampling" pass (skewed inputs may
overflow per-destination capacity — the driver surfaces that as a per-round
`n_dropped`); refinement stops THE ROUND the partition becomes good enough:
the job's halt_fn fires once a round is lossless (every record received) and
balanced (max reducer load within `balance`x of the fair share), so
well-conditioned inputs pay for one round instead of a fixed refinement
budget. Shapes are fixed every round, so each chunk is a single halt-masked
`lax.scan` under shard_map.

The (R, R·capacity) sorted table — by far the largest carried leaf — is
declared SHARDED (`P(axis)`) by default via the driver's two-tier
carried-state contract: each reducer keeps only its own row resident across
rounds, the per-round `all_gather` that used to re-replicate the table is
gone, and the full table materializes once, on the host, after the job
(`$REPRO_STATE_SPECS=replicated` or `shard_state=False` restores the
historical layout; outputs are bit-identical).
"""

from __future__ import annotations

import warnings

import numpy as np

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from jax.sharding import PartitionSpec as P

from repro.core.driver import IterativeSpec, resolve_state_mode, run_until
from repro.core.engine import identity_hash
from repro.core.shuffle import SecureShuffleConfig


def equidepth_edges(edges, counts):
    """Refine bin edges toward equi-depth given observed per-bin counts.

    Inverts the piecewise-linear CDF implied by (edges, counts) at the
    equi-depth targets. Endpoints stay pinned; empty histograms return the
    edges unchanged.
    """
    r = counts.shape[0]
    total = jnp.sum(counts)
    cum = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    targets = total * jnp.arange(1, r, dtype=jnp.float32) / r
    interior = jnp.interp(targets, cum.astype(jnp.float32), edges.astype(jnp.float32))
    new = jnp.concatenate([edges[:1], interior, edges[-1:]])
    return jnp.where(total > 0, new, edges)


def make_sample_sort_spec(n_shards: int, capacity: int, *, axis_name: str = "data",
                          n_rounds: int = 2, halt_total: int | None = None,
                          balance: float = 1.5,
                          shard_state: str | bool = "auto",
                          dynamic_total: bool = False) -> IterativeSpec:
    """Driver spec for sampling sort over `n_shards` reducers.

    State: {"edges": (R+1,) f32 range-partition edges (replicated),
            "sorted": (R, R*capacity) f32 per-reducer sorted ranges
                      (+inf padding past each reducer's count),
            "counts": (R,) f32 per-reducer received counts (replicated)}.

    `shard_state` picks the layout of the big "sorted" table — the driver's
    sharded-carried-state motivating workload. True/'sharded' (the 'auto'
    default via $REPRO_STATE_SPECS, see `driver.resolve_state_mode`)
    declares it `P(axis)`: each reducer keeps ONLY its own (1, R*capacity)
    row resident across rounds and the per-round all_gather of the full
    table disappears — per-device state shrinks ~Rx on an R-device mesh.
    False/'replicated' keeps the historical every-shard-holds-everything
    layout; the two are bit-identical after the final host gather (row i is
    reducer i's local sort either way). Splitter edges and counts stay
    replicated in both modes — refinement and halting read them.

    `halt_total` (the job's total record count) installs the refinement
    halt predicate: stop once a round received every record (lossless —
    counts sum to `halt_total`) AND no reducer holds more than `balance`
    times the fair share. Both terms are functions of the round's
    replicated `counts` aux, satisfying the driver's replicated-halt
    contract in either state layout.

    `dynamic_total=True` is the SERVING variant: the record total moves
    from a baked trace-time constant into a replicated "total" state leaf
    (read by the halt predicate at run time), and the map marks NON-FINITE
    records invalid so they never enter the shuffle or the counts. One
    compiled runner then serves any job padded (with +inf) up to the same
    bucket shape — different real sizes reuse the program instead of
    recompiling — and `state["total"]` carries each job's real count.
    `halt_total` is ignored in this mode; `balance` stays baked.
    """
    if isinstance(shard_state, bool):
        sharded = shard_state
    else:
        sharded = resolve_state_mode(shard_state) == "sharded"

    def map_fn(state, inputs, r):
        v = inputs["v"]
        # destination reducer by range partition on the current edges
        bucket = jnp.clip(
            jnp.searchsorted(state["edges"][1:-1], v, side="right"), 0, n_shards - 1
        ).astype(jnp.int32)
        if dynamic_total:
            # bucket-padding records (+inf) are invalid: bucket_pack drops
            # keys < 0 without counting them, so padding is never shuffled
            bucket = jnp.where(jnp.isfinite(v), bucket, jnp.int32(-1))
        return bucket, {"v": v}

    def reduce_fn(state, rk, rv, valid, r):
        recv = jnp.where(valid, rv["v"], jnp.inf)
        local_sorted = jnp.sort(recv)  # invalids sort last as +inf
        local_count = jnp.sum(valid).astype(jnp.float32)

        # counts must replicate (they drive refinement + halting) ...
        counts = lax.all_gather(local_count, axis_name)
        if sharded:
            # ... but the sorted table stays RESIDENT: this reducer's row is
            # its local shard of the P(axis) leaf — no client gather
            table = local_sorted[None, :]
        else:
            # client gather: every shard reassembles the full table
            table = lax.all_gather(local_sorted, axis_name)
        new_state = {
            "edges": equidepth_edges(state["edges"], counts),
            "sorted": table,
            "counts": counts,
        }
        if dynamic_total:
            new_state["total"] = state["total"]
        return new_state, {"counts": counts}

    halt_fn = None
    if dynamic_total:
        bal = jnp.float32(balance)

        def halt_fn(state, aux, r):
            counts = aux["counts"]
            total = state["total"]
            fair = bal * total / jnp.float32(n_shards)
            return (jnp.sum(counts) >= total) & (jnp.max(counts) <= fair)
    elif halt_total is not None:
        fair = jnp.float32(balance * halt_total / n_shards)
        total = jnp.float32(halt_total)

        def halt_fn(state, aux, r):
            counts = aux["counts"]
            return (jnp.sum(counts) >= total) & (jnp.max(counts) <= fair)

    state_specs = {
        "edges": P(),
        "sorted": P(axis_name) if sharded else P(),
        "counts": P(),
    }
    if dynamic_total:
        state_specs["total"] = P()
    return IterativeSpec(
        map_fn=map_fn,
        reduce_fn=reduce_fn,
        hash_fn=identity_hash,  # key IS the destination reducer
        capacity=capacity,
        n_rounds=n_rounds,
        halt_fn=halt_fn,
        state_specs=state_specs,
    )


def sample_sort(
    values,
    mesh: Mesh,
    *,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    n_rounds: int = 2,
    capacity: int | None = None,
    lo: float | None = None,
    hi: float | None = None,
    balance: float = 1.5,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    shard_state: str | bool = "auto",
):
    """Sort `values` (f32, sharded on the leading dim) via sampling sort.

    Returns (sorted_values, counts (R,), dropped (rounds_executed,)): row i
    of the carried buffer holds reducer i's sorted range, so concatenating
    each row's first counts[i] entries in row order — no global re-sort —
    yields the sorted array (length n minus any final-round drops).
    `capacity` is per-(source, destination) slots; defaults to the lossless
    worst case (a whole source shard landing in one range).

    `n_rounds` is the refinement BUDGET, not a fixed cost: the job runs
    through the convergence-aware driver (`run_until`) and halts the round
    the partition is lossless and balanced within `balance`x of fair share
    — `len(dropped)` reports how many rounds actually executed.
    `chacha_impl` selects the secure keystream backend and `coalesce` the
    wire layout (see `core/shuffle.py`); `loop_impl` the halt-loop shape
    and `shard_state` the layout of the carried sorted table
    (`make_sample_sort_spec`; 'auto' reads $REPRO_STATE_SPECS, default
    sharded — bit-identical output either way, the sharded table is simply
    gathered once at the end instead of every round).
    """
    values = jnp.asarray(values, jnp.float32)
    n = values.shape[0]
    r = mesh.shape[axis_name]
    n_loc = n // r
    if capacity is None:
        capacity = n_loc  # lossless even if a source sends everything one way
    if lo is None:
        lo = float(jnp.min(values))
    if hi is None:
        hi = float(jnp.max(values))
    # open the top edge so hi itself stays in the last bucket
    span = max(hi - lo, 1e-6)
    edges = jnp.asarray(lo + span * jnp.arange(r + 1) / r, jnp.float32)
    edges = edges.at[-1].set(hi + 1e-3 * span)

    init_state = {
        "edges": edges,
        "sorted": jnp.full((r, r * capacity), jnp.inf, jnp.float32),
        "counts": jnp.zeros((r,), jnp.float32),
    }
    spec = make_sample_sort_spec(r, capacity, axis_name=axis_name,
                                 halt_total=n, balance=balance,
                                 shard_state=shard_state)
    # early-round overflow is the sampling phase working as designed, not a
    # sizing bug — keep the driver's per-round warning quiet and instead
    # surface the case that IS data loss: drops in the final executed round
    res = run_until(
        spec, {"v": values}, init_state, mesh, axis_name, secure=secure,
        max_rounds=n_rounds, chacha_impl=chacha_impl, loop_impl=loop_impl,
        coalesce=coalesce, warn_on_overflow=False,
    )
    if res.dropped.size and int(res.dropped[-1]) > 0:
        warnings.warn(
            f"sample_sort exhausted its {n_rounds}-round refinement budget "
            f"with {int(res.dropped[-1])} records dropped in the final round "
            f"(per-(source,destination) capacity {capacity}); the output is "
            f"TRUNCATED — raise capacity or n_rounds",
            RuntimeWarning, stacklevel=2)

    rows = np.asarray(res.state["sorted"])
    counts = np.asarray(res.state["counts"])
    out = np.concatenate([rows[i, : int(counts[i])] for i in range(r)])
    return out, counts, res.dropped

"""Word count — the paper's Listing 1/2 example, on the secure engine.

The paper's Lua mapper emits (word, 1), the combiner sums value lists per
key, `hash(key, rcount)` picks the reducer, and the reducer sums again. Here
"words" are token ids over a fixed vocabulary; the combiner is a local
bincount so the shuffle carries at most |V| pairs per mapper — the same
role json-encoded value lists play in the paper.

User code (`map_fn`/`combine_fn`/`reduce_fn` below) is ~20 lines — matching
the paper's "<30 LOC" claim; `benchmarks/bench_tcb.py` counts it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import MapReduceSpec, identity_hash, run_mapreduce
from repro.core.shuffle import SecureShuffleConfig


def wordcount(
    tokens,
    vocab_size: int,
    mesh: Mesh,
    *,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
):
    """Histogram of `tokens` (int32, sharded) over [0, vocab_size)."""

    def map_fn(keys, values):  # emit (word, 1)
        return keys, values

    def combine_fn(keys, values):  # local bincount -> (vocab, count) pairs
        counts = jax.ops.segment_sum(values, jnp.where(keys >= 0, keys, 0), num_segments=vocab_size)
        ks = jnp.arange(vocab_size, dtype=jnp.int32)
        ks = jnp.where(counts > 0, ks, -1)  # empty words: padding
        return ks, counts

    def reduce_fn(keys, values, valid):  # sum grouped values
        seg = jnp.where(valid, keys, 0)
        out = jax.ops.segment_sum(jnp.where(valid, values, 0.0), seg, num_segments=vocab_size)
        return lax.psum(out, axis_name)

    spec = MapReduceSpec(
        map_fn=map_fn,
        combine_fn=combine_fn,
        reduce_fn=reduce_fn,
        hash_fn=identity_hash,  # paper: first byte of key % rcount
        capacity=-(-vocab_size // mesh.shape[axis_name]),
    )
    tokens = jnp.asarray(tokens, jnp.int32)
    ones = jnp.ones(tokens.shape, jnp.float32)
    counts, dropped = run_mapreduce(
        spec, tokens, ones, mesh, axis_name=axis_name, secure=secure
    )
    return counts, dropped

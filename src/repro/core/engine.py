"""Device-level secure MapReduce engine (shard_map pipeline).

One jitted program runs the full paper pipeline on a mesh axis:

    split (sharded input)
      └─ map_fn        per-shard, vectorized ("mapper enclave")
      └─ combine_fn    optional local pre-aggregation (paper's combiner)
      └─ bucket_pack   hash(key) % R  →  (R, C, ...) send buffer
      └─ keyed_all_to_all   [+ ChaCha20 on the wire in secure mode]
      └─ reduce_fn     per-shard over received pairs ("reducer enclave")

All user functions are vectorized fixed-shape JAX functions (or SecVM
programs via `repro.core.secvm.secvm_map_fn` for code confidentiality).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.shuffle import SecureShuffleConfig, bucket_pack, keyed_all_to_all


def default_hash(keys):
    """Knuth multiplicative mix — the paper's `hash(key, rcount)` slot."""
    return (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> 1


def identity_hash(keys):
    return keys.astype(jnp.uint32)


@dataclass(frozen=True)
class MapReduceSpec:
    """A MapReduce job over fixed-shape shards.

    map_fn(keys, values)            -> (mapped_keys, mapped_values)
    combine_fn(keys, values)        -> (keys, values)  [optional, local]
    reduce_fn(keys, values, valid)  -> per-shard output (typically followed by
                                       a psum/all_gather the caller encodes
                                       inside reduce_fn itself)
    hash_fn(keys) -> u32            destination = hash_fn(k) % R
    capacity: per-destination slots C (like MoE capacity factor).
    """

    map_fn: Callable[[Any, Any], tuple]
    reduce_fn: Callable[[Any, Any, Any], Any]
    combine_fn: Callable[[Any, Any], tuple] | None = None
    hash_fn: Callable = default_hash
    capacity: int = 0  # 0 → auto: ceil(n_mapped / R) * 2


def _shard_body(keys, values, *, spec: MapReduceSpec, axis_name: str, n_shards: int,
                secure: SecureShuffleConfig | None):
    mk, mv = spec.map_fn(keys, values)
    if spec.combine_fn is not None:
        mk, mv = spec.combine_fn(mk, mv)
    n_mapped = mk.shape[0]
    capacity = spec.capacity or max(1, -(-n_mapped // n_shards) * 2)

    bucket = (spec.hash_fn(mk) % jnp.uint32(n_shards)).astype(jnp.int32)
    bk, bv, dropped = bucket_pack(mk, bucket, mv, n_shards, capacity)

    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure)
    rk, rv = recv["k"], recv["v"]

    flat_k = rk.reshape(-1)
    flat_v = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), rv)
    valid = flat_k >= 0
    out = spec.reduce_fn(flat_k, flat_v, valid)
    return out, lax.psum(dropped, axis_name)


def run_mapreduce(
    spec: MapReduceSpec,
    keys,
    values,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    out_specs=P(),
    chacha_impl: str | None = None,
    coalesce: bool | None = None,
):
    """Run the pipeline over `mesh[axis_name]`. Inputs are host-global arrays
    sharded on their leading dim; output spec defaults to replicated (the
    usual case: reduce_fn ends in a psum/all_gather).

    `chacha_impl` overrides the secure config's keystream backend
    ('pallas' | 'pallas-interpret' | 'jnp') and `coalesce` its wire layout
    (True — single coalesced wire, one all_to_all — False — per-leaf
    oracle; see `core/shuffle.py`).

    Returns (output, n_dropped) — n_dropped must be 0 for a lossless job.
    """
    if secure is not None:
        secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
    n_shards = mesh.shape[axis_name]
    body = partial(_shard_body, spec=spec, axis_name=axis_name, n_shards=n_shards, secure=secure)
    in_specs = (P(axis_name), compat.tree_map(lambda _: P(axis_name), values))
    fn = compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=(out_specs, P()), check_vma=False
    )
    return jax.jit(fn)(keys, values)


def run_mapreduce_until(
    spec: MapReduceSpec,
    keys,
    values,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    halt_fn,
    fold_fn=None,
    max_rounds: int = 16,
    secure: SecureShuffleConfig | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    min_chunk: int = 1,
    growth: int = 2,
    max_chunk: int | None = None,
):
    """Repeat a single-round MapReduce job until `halt_fn` says stop.

    Lifts `spec` into the convergence-aware iterative driver: every round
    re-maps the same sharded (keys, values), reduces per shard, folds the
    round's reduce output into the carried state via
    `fold_fn(state, round_output)` (default: the output REPLACES the
    state), then evaluates `halt_fn(state, round_output, round_index)` on
    the folded state — all inside the fused, halt-masked round loop of
    `repro.core.driver.run_until` (adaptive dispatch chunking, on-device
    early exit, per-round disjoint keystreams in secure mode). The driver's
    replicated-halt contract applies: `spec.reduce_fn` must end in a
    collective and `halt_fn` must depend only on replicated values.

    Returns the driver's `RunUntilResult` (state, per-round aux = the raw
    reduce outputs, rounds executed vs dispatched, halted).
    """
    # local import: driver imports this module for default_hash
    from repro.core.driver import IterativeSpec, run_until

    def map_fn(state, inputs, r):
        return spec.map_fn(inputs["k"], inputs["v"])

    def reduce_fn(state, rk, rv, valid, r):
        out = spec.reduce_fn(rk, rv, valid)
        new_state = out if fold_fn is None else fold_fn(state, out)
        return new_state, out

    ispec = IterativeSpec(
        map_fn=map_fn,
        reduce_fn=reduce_fn,
        combine_fn=spec.combine_fn,
        hash_fn=spec.hash_fn,
        capacity=spec.capacity,
        halt_fn=halt_fn,
        # lifted single-round jobs fold the whole reduce output into state
        # and hand it to halt_fn — replicated everywhere by construction
        state_specs=P(),
    )
    return run_until(
        ispec, {"k": keys, "v": values}, init_state, mesh, axis_name,
        secure=secure, max_rounds=max_rounds, min_chunk=min_chunk,
        growth=growth, max_chunk=max_chunk, chacha_impl=chacha_impl,
        loop_impl=loop_impl, coalesce=coalesce,
    )

"""SecVM — code confidentiality via an in-graph bytecode interpreter.

The paper ports a Lua VM *into the enclave* so user map/reduce code ships as
encrypted scripts the host never sees. XLA has no enclave, but it has the
same structural opportunity: compile ONE generic interpreter; ship the user
program as *data* (encrypted int32 bytecode + f32 constant pool), decrypted
and executed inside the jitted computation. The lowered HLO is identical for
any two programs of the same length — the platform observes the interpreter,
not the algorithm (tested in tests/test_secvm.py).

Machine model: NREG vector registers of shape (lanes,) f32; a program is a
(L, 4) int32 array of [opcode, dst, a, b]; constants live in a separate pool
(register-indexed LOADC). Execution is a `lax.fori_loop` whose body applies
`lax.switch` over opcodes — one dynamic dispatch per instruction, fully
shape-static.

This is deliberately a small machine (enough for elementwise math — feature
transforms, distances, activations); the fast path for production jobs
remains plain JAX map/reduce functions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.crypto.ctr import decrypt_array, encrypt_array

NREG = 16

OPS = {
    "NOP": 0,
    "MOV": 1,    # r[d] = r[a]
    "LOADC": 2,  # r[d] = const[b]
    "ADD": 3,    # r[d] = r[a] + r[b]
    "SUB": 4,
    "MUL": 5,
    "DIV": 6,
    "MIN": 7,
    "MAX": 8,
    "NEG": 9,
    "ABS": 10,
    "SQRT": 11,
    "EXP": 12,
    "LOG": 13,
    "FLOOR": 14,
    "CMPLT": 15,  # r[d] = r[a] < r[b] ? 1 : 0
    "FMA": 16,    # r[d] = r[d] + r[a] * r[b]
    "MOD": 17,    # r[d] = r[a] mod r[b]
}
N_OPS = len(OPS)


@dataclass(frozen=True)
class Program:
    """Assembled SecVM program."""

    code: np.ndarray  # (L, 4) int32
    consts: np.ndarray  # (NC,) float32
    out_reg: int = 0

    @property
    def length(self) -> int:
        return int(self.code.shape[0])


def assemble(instrs: Sequence[tuple], consts: Sequence[float] = (), out_reg: int = 0) -> Program:
    """instrs: [("ADD", d, a, b), ("LOADC", d, 0, const_idx), ...]"""
    code = np.zeros((len(instrs), 4), np.int32)
    for i, ins in enumerate(instrs):
        name, *ops = ins
        code[i, 0] = OPS[name]
        code[i, 1 : 1 + len(ops)] = ops
    return Program(code=code, consts=np.asarray(consts, np.float32), out_reg=out_reg)


def _exec_instr(regs, consts, instr):
    op, d, a, b = instr[0], instr[1], instr[2], instr[3]
    ra = regs[a]
    rb = regs[b]
    rd = regs[d]
    cb = consts[b]

    branches = [
        lambda: rd,  # NOP
        lambda: ra,  # MOV
        lambda: jnp.broadcast_to(cb, rd.shape),  # LOADC
        lambda: ra + rb,
        lambda: ra - rb,
        lambda: ra * rb,
        lambda: ra / rb,
        lambda: jnp.minimum(ra, rb),
        lambda: jnp.maximum(ra, rb),
        lambda: -ra,
        lambda: jnp.abs(ra),
        lambda: jnp.sqrt(ra),
        lambda: jnp.exp(ra),
        lambda: jnp.log(ra),
        lambda: jnp.floor(ra),
        lambda: (ra < rb).astype(jnp.float32),
        lambda: rd + ra * rb,
        lambda: ra - jnp.floor(ra / rb) * rb,
    ]
    val = lax.switch(jnp.clip(op, 0, N_OPS - 1), branches)
    return regs.at[d].set(val)


def run_program(code, consts, inputs, out_reg=0, length: int | None = None):
    """Execute bytecode on vector lanes.

    code:   (L, 4) int32 (may be a traced array — e.g. freshly decrypted)
    consts: (NC,) f32
    inputs: (n_in, lanes) f32 loaded into r1..r{n_in} (r0 zeroed: output acc)
    """
    lanes = inputs.shape[1]
    regs = jnp.zeros((NREG, lanes), jnp.float32)
    regs = regs.at[1 : 1 + inputs.shape[0]].set(inputs)
    n = length if length is not None else code.shape[0]

    def body(i, regs):
        return _exec_instr(regs, consts, code[i])

    regs = lax.fori_loop(0, n, body, regs)
    return regs[out_reg]


# ---------------------------------------------------------------------------
# Encrypted-program transport ("provisioning of code", paper Fig. 4)
# ---------------------------------------------------------------------------


def encrypt_program(prog: Program, key_words, nonce_words, counter0=0):
    """Returns (code_ct, consts_ct) — ciphertext arrays safe to hand the host."""
    code_ct = encrypt_array(jnp.asarray(prog.code), key_words, nonce_words, counter0)
    c_blocks = -(-prog.code.size // 16)
    consts_ct = encrypt_array(
        jnp.asarray(prog.consts), key_words, nonce_words, counter0 + c_blocks
    )
    return code_ct, consts_ct


def run_encrypted(code_ct, consts_ct, inputs, key_words, nonce_words, counter0=0, out_reg=0):
    """Decrypt *inside* the computation and execute. jit-safe end to end."""
    code = decrypt_array(code_ct, key_words, nonce_words, counter0)
    c_blocks = -(-code_ct.size // 16)
    consts = decrypt_array(consts_ct, key_words, nonce_words, counter0 + c_blocks)
    return run_program(code, consts, inputs, out_reg=out_reg)


# -- python oracle for tests --------------------------------------------------


def run_oracle(prog: Program, inputs: np.ndarray) -> np.ndarray:
    regs = np.zeros((NREG, inputs.shape[1]), np.float32)
    regs[1 : 1 + inputs.shape[0]] = inputs
    inv = {v: k for k, v in OPS.items()}
    with np.errstate(all="ignore"):
        for op, d, a, b in prog.code:
            name = inv[int(op)]
            if name == "NOP":
                continue
            elif name == "MOV":
                regs[d] = regs[a]
            elif name == "LOADC":
                regs[d] = prog.consts[b]
            elif name == "ADD":
                regs[d] = regs[a] + regs[b]
            elif name == "SUB":
                regs[d] = regs[a] - regs[b]
            elif name == "MUL":
                regs[d] = regs[a] * regs[b]
            elif name == "DIV":
                regs[d] = regs[a] / regs[b]
            elif name == "MIN":
                regs[d] = np.minimum(regs[a], regs[b])
            elif name == "MAX":
                regs[d] = np.maximum(regs[a], regs[b])
            elif name == "NEG":
                regs[d] = -regs[a]
            elif name == "ABS":
                regs[d] = np.abs(regs[a])
            elif name == "SQRT":
                regs[d] = np.sqrt(regs[a])
            elif name == "EXP":
                regs[d] = np.exp(regs[a])
            elif name == "LOG":
                regs[d] = np.log(regs[a])
            elif name == "FLOOR":
                regs[d] = np.floor(regs[a])
            elif name == "CMPLT":
                regs[d] = (regs[a] < regs[b]).astype(np.float32)
            elif name == "FMA":
                regs[d] = regs[d] + regs[a] * regs[b]
            elif name == "MOD":
                regs[d] = regs[a] - np.floor(regs[a] / regs[b]) * regs[b]
    return regs[prog.out_reg]

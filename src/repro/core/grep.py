"""Multi-round streaming grep on the iterative secure MapReduce driver.

The grep workload from the MapReduce canon: mappers scan records for the
patterns, emit (pattern_id, 1) per hit, reducers sum per pattern. Here the
corpus is processed as a *stream*: each shard holds n_rounds chunks, each
executed round maps the next one (`lax.dynamic_slice` on a stream CURSOR
carried in state — NOT on the global round index, which is shifted by
`round_offset` for jobs admitted into a shared serving session; see the
driver's Serving section), and the running per-pattern hit counts ride in
the same carried state. One fused dispatch greps the whole corpus — the
round loop never leaves the device, and in secure mode every round's
shuffle draws a disjoint keystream via the round-index nonce layout in
`core/shuffle.py`.

Patterns are token ids over a fixed vocabulary (the same modeling of "words"
as `core/wordcount.py`); a hit is an exact token match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.driver import IterativeSpec, run_until
from repro.core.engine import identity_hash
from repro.core.shuffle import SecureShuffleConfig


def make_grep_spec(patterns, chunk: int, *, axis_name: str = "data",
                   max_matches: int | None = None) -> IterativeSpec:
    """Driver spec: state = {"hits": running (n_patterns,) counts,
    "cursor": () u32 stream position} — both replicated.

    The cursor, not the global round index, selects the next chunk of the
    per-shard stream: it advances by one per EXECUTED round (halted rounds
    advance neither the cursor nor the keystream), which makes the spec
    offset-agnostic — a serving session can hand the job any
    `round_offset` base for keystream disjointness without the stream
    skipping ahead.

    `max_matches` installs a `grep -m`-style halt: stop streaming once the
    TOTAL hit count (summed over patterns) reaches the limit. The running
    counts are replicated state (reduce ends in a psum), so the halt
    decision satisfies the driver's replicated-halt contract.

    Pattern-matching treats tokens < 0 as padding (they match no pattern
    and never enter the shuffle), so inputs padded up to a serving bucket
    with -1 tokens count identically to the unpadded stream.
    """
    patterns = jnp.asarray(patterns, jnp.int32)
    n_pat = patterns.shape[0]

    def map_fn(state, inputs, r):
        start = (state["cursor"].astype(jnp.int32) * chunk,)
        toks = lax.dynamic_slice(inputs["t"], start, (chunk,))
        # pattern id per token, -1 (engine padding) where nothing matches
        eq = toks[:, None] == patterns[None, :]
        pid = jnp.where(jnp.any(eq, axis=1), jnp.argmax(eq, axis=1), -1).astype(jnp.int32)
        return pid, {"one": jnp.ones((chunk,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        seg = jnp.where(valid, rk, 0)
        hits = jax.ops.segment_sum(jnp.where(valid, rv["one"], 0.0), seg,
                                   num_segments=n_pat)
        hits = lax.psum(hits, axis_name)
        new_state = {"hits": state["hits"] + hits,
                     "cursor": state["cursor"] + jnp.uint32(1)}
        return new_state, {"round_hits": hits}

    halt_fn = None
    if max_matches is not None:
        limit = jnp.float32(max_matches)

        def halt_fn(state, aux, r):
            return jnp.sum(state["hits"]) >= limit

    return IterativeSpec(
        map_fn=map_fn,
        reduce_fn=reduce_fn,
        hash_fn=identity_hash,  # reducer = pattern_id % R
        capacity=chunk,  # lossless: a chunk may be all one pattern
        halt_fn=halt_fn,  # n_rounds is chosen per chunk by run_until
        # running counts are tiny and the halt predicate reads them —
        # explicitly replicated under the driver's two-tier state contract
        state_specs=P(),
    )


def grep_count(
    tokens,
    patterns,
    mesh: Mesh,
    *,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    n_rounds: int = 4,
    max_matches: int | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
):
    """Count occurrences of each pattern token in `tokens` (int32, sharded).

    The per-shard stream is split into `n_rounds` chunks processed by
    successive fused rounds (the stream cursor is carried in state and
    advances per EXECUTED round, so the job is round_offset-agnostic — and
    the convergence-aware driver resumes exactly where the stream stopped,
    because halted rounds advance neither the cursor nor the keystream).
    Returns
    (counts (n_patterns,), per_round_hits (rounds_executed, n_patterns),
    dropped (rounds_executed,)).

    `max_matches` is a `grep -m`-style early exit: streaming stops the
    round the TOTAL hit count reaches the limit, through `run_until` with
    adaptive chunking, so a limit met in chunk 2 of 64 never dispatches the
    remaining corpus. Without it the whole stream runs as one fused
    dispatch, exactly as before. `chacha_impl` selects the secure keystream
    backend (see `core/shuffle.py`); `loop_impl` the halt-loop shape
    (`core/driver.py`).
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    n = tokens.shape[0]
    r = mesh.shape[axis_name]
    n_loc = n // r
    if n != n_loc * r or n_loc % n_rounds != 0:
        raise ValueError(f"n={n} must split into {r} shards x {n_rounds} chunks")
    chunk = n_loc // n_rounds

    patterns = jnp.asarray(patterns, jnp.int32)
    spec = make_grep_spec(patterns, chunk, axis_name=axis_name,
                          max_matches=max_matches)
    init = {"hits": jnp.zeros((patterns.shape[0],), jnp.float32),
            "cursor": jnp.uint32(0)}
    # no limit -> one fused dispatch of the whole stream (min_chunk covers
    # every round); with a limit, start small and grow geometrically
    min_chunk = n_rounds if max_matches is None else 1
    res = run_until(
        spec, {"t": tokens}, init, mesh, axis_name, secure=secure,
        max_rounds=n_rounds, min_chunk=min_chunk,
        chacha_impl=chacha_impl, loop_impl=loop_impl, coalesce=coalesce,
    )
    return res.state["hits"], res.aux["round_hits"], res.dropped

"""Iterative secure MapReduce driver: N rounds inside ONE jitted dispatch.

Why
---
The paper's headline workload — k-means — is an *iterative* MapReduce job,
yet `repro.core.engine.run_mapreduce` executes exactly one
map→shuffle→reduce round per dispatch, so every iteration pays a host
round-trip, fresh argument transfers, and (in secure mode) re-derived
keystream setup. SGX-MR (arXiv:2009.03518) makes the same observation for
enclaves: regulating the whole dataflow inside the trusted boundary, not
per-round hops through untrusted orchestration, is what keeps overhead low.
This driver runs the full round loop as a single `lax.scan` under
`shard_map`, so a converged k-means run costs O(n_rounds / rounds_per_dispatch)
host round-trips instead of O(n_rounds).

Round structure
---------------
Each round r of `run_iterative_mapreduce` executes, per shard:

    mapped_k, mapped_v = spec.map_fn(state, inputs, r)      # "mapper enclave"
    [mapped_k, mapped_v = spec.combine_fn(mapped_k, mapped_v)]
    bucket  = spec.hash_fn(mapped_k) % R
    send    = bucket_pack(...)                              # fixed (R, C, ...)
    recv    = keyed_all_to_all(send, axis, secure, round_index=r)
    state, aux = spec.reduce_fn(state, keys, values, valid, r)   # "reducer"

and the scan threads `state` (e.g. k-means centroids) into the next round.
Per-round aux (stacked over rounds) and per-round overflow counts
(`n_dropped`, psum'd over shards) come back to the host so convergence can
be judged — and a mid-chunk convergence point recovered from aux — without
re-entering the device loop.

Termination
-----------
Fixed `n_rounds` is the wrong contract for convergence-driven jobs: after
the centroids stop moving, every remaining round in the chunk still pays the
full map → bucket_pack → encrypt → all_to_all → decrypt → reduce pipeline.
`IterativeSpec.halt_fn(state, aux, round_index) -> bool` moves the
termination decision on-device, and `run_until` stops paying for
post-convergence rounds at two levels:

  * ON-DEVICE the round loop is halt-aware. `halt_fn` is evaluated right
    after each round's reduce, on the freshly reduced (replicated) state and
    that round's aux; once it returns True the remaining rounds of the chunk
    become no-ops. Two interchangeable loop shapes implement this (select
    with `loop_impl`, default `DEFAULT_HALT_LOOP` = 'while'):
      - 'while'      — a `lax.while_loop` whose predicate is
        `~halted & (i < n_rounds)`, writing aux into preallocated buffers;
      - 'masked_scan' — the fixed-length `lax.scan` is kept, but a
        `lax.cond` gates the whole round body into a cheap passthrough
        (state unchanged, zero aux, no shuffle) once halted.
    Both return `(state, aux, dropped, rounds_executed, halted)` and are
    bit-identical; `benchmarks/bench_iteration_time.py` measures both (the
    while loop compiles ~2x faster and skips the masked tail entirely,
    hence the default; see the note at `DEFAULT_HALT_LOOP`).

    REPLICATED-HALT CONTRACT: `halt_fn` must be a pure function of
    replicated values (the carried state — which `reduce_fn` must replicate
    before returning — the aux derived from it, and the round index). All
    shards then compute the same predicate by construction, so the
    collectives inside `lax.cond` / `lax.while_loop` branch uniformly
    across the mesh. A halt decision derived from shard-local data is a
    deadlock (shards disagree about whether the all_to_all happens).

  * KEYSTREAM ACCOUNTING FOR HALTED ROUNDS: a halted round consumes NO
    keystream — the passthrough branch performs no encryption and no
    collective (`record_wire_bytes` shows zero bytes for it). The global
    round index keeps advancing per *executed* round only: `run_until`
    feeds each chunk's returned `rounds_executed` into the next chunk's
    `round_offset`, so executed rounds worldwide occupy the disjoint,
    gapless counter range [round_offset, round_offset + total_executed).
    Round indices skipped by a halted chunk tail were never used to derive
    keystream, so re-issuing them to the next chunk cannot reuse a pad.

  * ON THE HOST `run_until` dispatches adaptively sized chunks: starting at
    `min_chunk` rounds and growing geometrically (×`growth`, capped at
    `max_chunk`), so a job converging in 7 rounds never dispatches — or
    compiles — a 32-round program, while long jobs still amortize host
    round-trips at the full chunk size.

Carried-state contract (two tiers: replicated | sharded)
--------------------------------------------------------
Each leaf of `state` lives in one of two layouts, chosen PER LEAF by
`IterativeSpec.state_specs` — a pytree of `jax.sharding.PartitionSpec`s
matching the state's structure (None, the default, means `P()` everywhere
and preserves the historical all-replicated contract bit-for-bit):

  * REPLICATED leaf — `P()`: every shard holds the same value on entry,
    and `reduce_fn` must restore replication before returning (end in a
    collective — psum / all_gather — exactly like the paper's "client
    redistributes the new centers" step). A reduce_fn that returns
    shard-varying data in a replicated leaf is a bug the shuffle cannot
    fix.
  * SHARDED leaf — `P(axis)`: the leaf stays partitioned over the mesh
    axis ACROSS rounds, resident where it was produced. Inside the round
    body `map_fn`/`reduce_fn` see the LOCAL shard (leading dim divided by
    the axis size) and `reduce_fn` returns the updated LOCAL shard — no
    re-replicating gather at the end of the round. This is what removes
    the per-round all_gather for large per-reducer state (sort output,
    join tables): per-device state bytes shrink by ~the axis size and the
    round loses a collective, with zero new collectives introduced
    (proven by jaxpr inspection in `tests/test_sharded_state.py`).

  RESHARDING RULE: the driver NEVER reshards carried state between rounds
  or between chunks. The spec declared for a leaf is simultaneously (a) the
  layout of the value `reduce_fn` must return every round, (b) the layout
  the next round's `map_fn`/`reduce_fn` receive, and (c) the layout of the
  final state a runner returns — a global jax.Array; `np.asarray` (or any
  host read) gathers it AFTER the loop, which is the one-time cost sharded
  mode defers from every round to the end of the job.

  HALT-FN RESTRICTION: `halt_fn` stays a pure function of REPLICATED
  values only — replicated state leaves, the (replicated) aux, and the
  round index. The driver enforces this at trace time: sharded leaves are
  replaced by guard objects in the state `halt_fn` sees, and touching one
  raises a ValueError naming the leaf. (A halt predicate over shard-local
  data is a deadlock: shards would disagree about whether the next
  round's collectives execute.)

  DONATION is layout-agnostic: `donate_state=True` aliases sharded leaves'
  per-device buffers exactly like replicated ones — `run_until`'s chunk
  loop keeps sharded state resident on its devices with zero copies
  between chunks.

The driver shards `inputs` over the mesh axis and replicates `aux`
(out_specs `P()`); aux must therefore be replicated by `reduce_fn` just
like replicated state leaves.

Counter-space layout (extends core/shuffle.py)
----------------------------------------------
A multi-round job performs many encrypted shuffles under one session key.
The per-shuffle layout (nonce word 0 ^= source index, counter = ctr0 +
leaf_offset + dest_row·blocks_per_row) is unchanged; the driver additionally
XORs the round index into nonce word 1 via
`keyed_all_to_all(..., round_index=r)`. The keystream spaces of distinct
rounds are therefore disjoint by construction — reusing one (as the
per-round Python loop historically did, re-dispatching with an identical
nonce/counter every iteration) is a two-time pad. The round index is part
of the replicated loop state; both endpoints derive the keystream locally
and nothing about it crosses the wire.

The index is GLOBAL across dispatches: a convergence loop that calls the
same runner in chunks passes `round_offset` = rounds already executed, so
chunk 2 continues at round n_rounds, not back at round 0 (which would
reuse chunk 1's keystreams). `run_until` does exactly this with each
chunk's `rounds_executed`; `kmeans_fit` and the other convergence loops
inherit the contract by running on it.

Workloads on the driver: `repro.core.kmeans` (paper §V), `repro.core.sort`
(TeraSort-style sampling sort with splitter refinement), `repro.core.grep`
(multi-round streaming grep) — all three terminate through `run_until`.

Serving (multi-tenant jobs over one persistent mesh)
----------------------------------------------------
`repro.serve.service.SecureJobService` runs MANY concurrent jobs through
this driver on one mesh and one `SecureShuffleConfig`. Two driver-level
contracts make that safe and cheap:

  * RUNNER-CACHE CONTRACT: `run_until(runners=...)` accepts either the
    historical plain dict (chunk size -> runner) or ANY object exposing
    `get_or_build(n_rounds, build_fn) -> runner` — duck-typed, so the
    service's process-wide `RunnerCache` (keyed by workload spec identity
    x padded input bucket x chunk size x knob tuple, with hit/miss/evict
    counters and geometric size buckets) plugs in without this module
    importing serve code. Whatever the container, the cached runner MUST
    have been built from the same spec (sans n_rounds), mesh, secure
    config (including key/nonce material — it is baked into the traced
    program's closure), impl/coalesce knobs, and donation mode; the
    service guarantees this by keying on all of them.

  * ROUND_OFFSET DISJOINTNESS ACROSS JOBS: all jobs served under ONE
    session key share one (key, nonce, counter) space, distinguished only
    by the round index XORed into nonce word 1. The per-job contract above
    (gapless executed-rounds range [round_offset, round_offset +
    rounds_executed)) therefore extends across jobs: the service assigns
    each admitted job a round BASE from a monotone per-service counter
    advanced by the job's max_rounds budget, so concurrent jobs draw from
    provably disjoint keystream ranges no matter how their chunk
    dispatches interleave. A workload whose map_fn consumes the global
    round index as data (streaming cursors) must carry its own cursor in
    state instead (see `core/grep.py`) to stay offset-agnostic.

`run_until_chunks` is the cooperative form of `run_until`: a generator
that yields after every chunk dispatch, so a host scheduler can
round-robin many jobs' dispatches on one thread (each suspended generator
holds its own carried state, runner cache view, and round offset). The
overflow warning is per JOB — accumulated across chunks and emitted once,
with global round indices — rather than per dispatched chunk.

Tuning (calibrated `auto` knobs)
--------------------------------
Every perf knob this driver exposes has an `auto` mode that resolves, in
order: explicit argument -> environment variable -> calibrated cost model
-> historical default. The model activates ONLY when $REPRO_CALIBRATION
names a calibration JSON (written once per backend/device-count by
`PYTHONPATH=src python -m repro.perf.calibrate --out calibration.json`);
with it unset, every `auto` resolves to its historical default bit-for-bit.

    knob            resolver                 env var               default
    chunk growth    resolve_chunk_growth     $REPRO_CHUNK_GROWTH   2
    loop impl       resolve_halt_loop        $REPRO_HALT_LOOP      'while'
    auto capacity   resolve_capacity_factor  —                     2.0
    chacha impl     shuffle.resolve_chacha_impl  $REPRO_CHACHA_IMPL    'pallas'
    coalesce        shuffle.resolve_coalesce     $REPRO_SHUFFLE_COALESCE True
    bucket growth   serve.resolve_bucket_growth  $REPRO_BUCKET_GROWTH  2.0
    residency cap   serve.resolve_max_resident   $REPRO_SERVICE_MAX_RUNNERS unbounded

`repro/perf/model.py` documents what each recommendation minimizes;
`benchmarks/bench_costmodel.py` reports predicted-vs-measured error
(BENCH_costmodel.json `pred_error`) so the calibration stays honest, and
`launch/hillclimb.py --cell K` ranks full knob vectors offline by
predicted AdmissionSim makespan.
"""

from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.engine import default_hash
from repro.core.shuffle import (
    SecureShuffleConfig,
    bucket_pack,
    keyed_all_to_all,
    wire_accounting,
)

HALT_LOOP_IMPLS = ("masked_scan", "while")
# Measured on CPU with the pallas-interpret keystream
# (benchmarks/bench_iteration_time.py, secure k-means, 8-round chunk
# converging at round 5): 'while' compiles ~2x faster (34s vs 67s — the
# cond-gated scan traces the round body into an extra conditional branch)
# and is ~13% faster per executed round at steady state (it exits the loop
# instead of running the masked no-op tail), so it is the default.
# 'masked_scan' is the documented loser but is kept: its traced skip branch
# is what makes the zero-bytes-for-halted-rounds claim auditable via
# `record_wire_bytes`, and its aux layout matches the non-halting scan.
DEFAULT_HALT_LOOP = "while"

STATE_SPECS_ENV = "REPRO_STATE_SPECS"
_STATE_MODES = ("replicated", "sharded")


def resolve_state_mode(mode: str = "auto") -> str:
    """Resolve a carried-state layout selector to 'replicated' | 'sharded'.

    The env-matrix hook for workloads that support both layouts (e.g.
    `core/sort.py`): 'auto'/None defers to $REPRO_STATE_SPECS (default
    'sharded' — the layout this repo ships); an explicit mode always wins
    over the environment. Like the chacha/coalesce selectors, the choice is
    read at trace time.
    """
    from_env = False
    if mode in (None, "auto"):
        env_val = os.environ.get(STATE_SPECS_ENV)
        if env_val is None:
            return "sharded"
        mode, from_env = env_val.strip().lower(), True
    if mode not in _STATE_MODES:
        if from_env:
            raise ValueError(
                f"invalid ${STATE_SPECS_ENV}={mode!r} in the environment: "
                f"carried-state mode must be one of {_STATE_MODES} "
                f"(unset ${STATE_SPECS_ENV} to use the default 'sharded')")
        raise ValueError(
            f"carried-state mode must be one of {_STATE_MODES} or 'auto', "
            f"got {mode!r}")
    return mode


CHUNK_GROWTH_ENV = "REPRO_CHUNK_GROWTH"
HALT_LOOP_ENV = "REPRO_HALT_LOOP"


def _model_recommendation(knob: str, **ctx):
    """Calibrated-model answer for an `auto` knob, or None when no
    calibration is active (see `core/shuffle.py::_model_recommendation`)."""
    from repro.perf.model import recommendation

    return recommendation(knob, **ctx)


def resolve_halt_loop(loop_impl: str | None = None) -> str:
    """Resolve the halt-aware loop shape ('while' | 'masked_scan').

    An explicit value always wins; None/'auto' defers to $REPRO_HALT_LOOP,
    then to the calibrated cost model when one is active (the cond-gated
    scan traces the round body twice, so the model prices its compile at
    ~2x; `repro/perf/model.py`), then to the measured default
    `DEFAULT_HALT_LOOP` = 'while'.
    """
    from_env = False
    if loop_impl in (None, "auto"):
        env_val = os.environ.get(HALT_LOOP_ENV)
        if env_val is None:
            rec = _model_recommendation("halt_loop")
            loop_impl = DEFAULT_HALT_LOOP if rec is None else rec
        else:
            loop_impl, from_env = env_val.strip(), True
    if loop_impl not in HALT_LOOP_IMPLS:
        if from_env:
            raise ValueError(
                f"invalid ${HALT_LOOP_ENV}={loop_impl!r} in the environment: "
                f"loop_impl must be one of {HALT_LOOP_IMPLS} "
                f"(unset ${HALT_LOOP_ENV} to use the default "
                f"{DEFAULT_HALT_LOOP!r})")
        raise ValueError(
            f"loop_impl must be one of {HALT_LOOP_IMPLS}, got {loop_impl!r}")
    return loop_impl


def resolve_chunk_growth(growth="auto", *, min_chunk: int = 1,
                         max_rounds: int = 64,
                         max_chunk: int | None = None) -> int:
    """Resolve the chunk-ladder growth factor to a concrete int >= 1.

    An explicit int always wins; 'auto'/None defers to $REPRO_CHUNK_GROWTH,
    then to the calibrated cost model when one is active (which minimizes
    distinct-ladder-size compiles + dispatch round trips for THIS
    min_chunk/max_rounds window; `repro/perf/model.py`), then to the
    historical default 2.
    """
    from_env = False
    if growth in (None, "auto"):
        env_val = os.environ.get(CHUNK_GROWTH_ENV)
        if env_val is None:
            rec = _model_recommendation(
                "chunk_growth", min_chunk=min_chunk, max_rounds=max_rounds,
                max_chunk=max_chunk)
            return 2 if rec is None else int(rec)
        growth, from_env = env_val.strip(), True
    try:
        val = int(growth)
    except (TypeError, ValueError):
        val = 0
    if val < 1:
        if from_env:
            raise ValueError(
                f"invalid ${CHUNK_GROWTH_ENV}={growth!r} in the environment: "
                f"chunk growth must be an integer >= 1 "
                f"(unset ${CHUNK_GROWTH_ENV} to use the default 2)")
        raise ValueError(
            f"growth must be an integer >= 1 or 'auto', got {growth!r}")
    return val


def resolve_capacity_factor() -> float:
    """Headroom factor for the auto bucket capacity (ceil(n/R) * factor).

    Consults the calibrated cost model when one is active; the model only
    departs from the historical 2.0 when its calibration carries a
    deployment-measured key-skew entry (overflow silently drops records, so
    no generic probe may shrink this; `repro/perf/model.py`).
    """
    rec = _model_recommendation("capacity_factor")
    return 2.0 if rec is None else float(rec)


def _resolve_state_specs(spec: "IterativeSpec", state):
    """Resolve `spec.state_specs` against a concrete state pytree.

    Returns (spec_tree, flat_is_sharded): `spec_tree` mirrors the state's
    structure with one `PartitionSpec` per leaf (usable directly as
    shard_map in/out specs); `flat_is_sharded` flags, in flat leaf order,
    the leaves that carry a mesh axis. None (the whole attribute or a
    leaf) defaults to `P()` — the replicated contract — and a single bare
    `PartitionSpec` broadcasts to every leaf (so `state_specs=P()` declares
    any state shape fully replicated). Raises ValueError — at trace/build
    time, not inside the loop — when the declared tree does not match the
    state's structure or holds a non-PartitionSpec leaf.
    """
    flat, treedef = jax.tree_util.tree_flatten(state)
    if spec.state_specs is None:
        flat_specs = [P()] * len(flat)
    elif isinstance(spec.state_specs, P):
        flat_specs = [spec.state_specs] * len(flat)
    else:
        try:
            flat_specs = treedef.flatten_up_to(spec.state_specs)
        except ValueError as e:
            raise ValueError(
                "IterativeSpec.state_specs must be a pytree matching the "
                f"carried state's structure {treedef}; got "
                f"{spec.state_specs!r}") from e
        checked = []
        for i, p in enumerate(flat_specs):
            if p is None:
                p = P()
            if not isinstance(p, P):
                raise ValueError(
                    "IterativeSpec.state_specs leaves must be "
                    "jax.sharding.PartitionSpec (or None for replicated); "
                    f"leaf {i} is {p!r}")
            checked.append(p)
        flat_specs = checked
    sharded = [any(a is not None for a in tuple(p)) for p in flat_specs]
    return jax.tree_util.tree_unflatten(treedef, flat_specs), sharded


class _ShardedHaltGuard:
    """Trace-time stand-in for a sharded state leaf inside `halt_fn`.

    The replicated-halt contract (module docstring) forbids deriving the
    halt predicate from shard-varying data; sharded leaves are therefore
    swapped for these guards in the state `halt_fn` receives, and ANY use —
    arithmetic, jnp coercion, attribute access, iteration — raises a
    ValueError naming the leaf, at trace time, instead of deadlocking the
    mesh at run time.
    """

    def __init__(self, path: str, pspec):
        object.__setattr__(self, "_path", path)
        object.__setattr__(self, "_pspec", pspec)

    def _halt_guard_raise(self, *_a, **_k):
        raise ValueError(
            f"IterativeSpec.halt_fn touched the SHARDED carried-state leaf "
            f"state{self._path} (state_specs leaf {self._pspec}): the "
            "replicated-halt contract requires halt_fn to be a pure "
            "function of replicated values only (replicated state leaves, "
            "aux, round index) — a shard-varying predicate would deadlock "
            "the mesh. Derive the halt signal from a replicated leaf or "
            "from aux, or declare this leaf P() in state_specs.")

    def __getattr__(self, name):
        self._halt_guard_raise()

    def __repr__(self):
        return f"_ShardedHaltGuard(state{self._path}: {self._pspec})"


for _name in (
    "__jax_array__", "__array__", "__bool__", "__int__", "__float__",
    "__index__", "__len__", "__iter__", "__getitem__", "__neg__", "__pos__",
    "__abs__", "__invert__", "__add__", "__radd__", "__sub__", "__rsub__",
    "__mul__", "__rmul__", "__truediv__", "__rtruediv__", "__floordiv__",
    "__rfloordiv__", "__mod__", "__rmod__", "__pow__", "__rpow__",
    "__matmul__", "__rmatmul__", "__and__", "__rand__", "__or__", "__ror__",
    "__xor__", "__rxor__", "__lshift__", "__rlshift__", "__rshift__",
    "__rrshift__", "__lt__", "__le__", "__gt__", "__ge__", "__eq__",
    "__ne__", "__format__",
):
    setattr(_ShardedHaltGuard, _name, _ShardedHaltGuard._halt_guard_raise)


def _guard_state_for_halt(state, spec_tree, flat_sharded):
    """Swap sharded leaves for `_ShardedHaltGuard`s in halt_fn's state view."""
    if not any(flat_sharded):
        return state
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    flat_specs = treedef.flatten_up_to(spec_tree)
    guarded = [
        _ShardedHaltGuard(jax.tree_util.keystr(path), pspec) if sh else leaf
        for (path, leaf), pspec, sh in zip(paths_leaves, flat_specs, flat_sharded)
    ]
    return jax.tree_util.tree_unflatten(treedef, guarded)


@dataclass(frozen=True)
class IterativeSpec:
    """A multi-round MapReduce job over fixed-shape shards.

    map_fn(state, inputs, round_index) -> (mapped_keys, mapped_values)
        Per-shard, vectorized. `inputs` is the (local slice of the) sharded
        input pytree; `round_index` is a traced u32 scalar for round-varying
        behavior (streaming slices, phase switches). Sharded state leaves
        (see `state_specs`) arrive as their LOCAL shard.
    combine_fn(keys, values) -> (keys, values)
        Optional local pre-aggregation before the shuffle.
    reduce_fn(state, keys, values, valid, round_index) -> (new_state, aux)
        Per-shard over the received pairs. Replicated state leaves must be
        restored to replication (end in psum/all_gather); sharded leaves
        must be returned as the updated LOCAL shard in the declared layout
        (module docstring: Carried-state contract). `aux` is any pytree of
        per-round REPLICATED diagnostics (stacked over rounds by the scan).
    hash_fn(keys) -> u32
        destination shard = hash_fn(k) % R.
    capacity:  per-destination slots C; 0 -> auto (ceil(n_mapped / R) * 2).
    n_rounds:  rounds fused into one dispatch.
    halt_fn(state, aux, round_index) -> bool scalar  [optional]
        Convergence predicate, evaluated after every round on that round's
        freshly reduced state/aux. MUST depend only on replicated values so
        every shard agrees (module docstring: Termination); sharded state
        leaves are guarded at trace time and raise on use. When set, the
        fused loop stops executing rounds — and consuming keystream — as
        soon as it returns True; runners then also return
        (rounds_executed, halted).
    state_specs:  pytree of `jax.sharding.PartitionSpec` matching the
        carried state's structure, choosing each leaf's cross-round layout:
        `P()` (or None) = replicated — the default everywhere when
        `state_specs` is None, preserving the historical contract
        bit-for-bit — `P(axis)` = resident-sharded over the mesh axis
        (module docstring: Carried-state contract).
    """

    map_fn: Callable[[Any, Any, Any], tuple]
    reduce_fn: Callable[[Any, Any, Any, Any, Any], tuple]
    combine_fn: Callable[[Any, Any], tuple] | None = None
    hash_fn: Callable = default_hash
    capacity: int = 0
    n_rounds: int = 1
    halt_fn: Callable[[Any, Any, Any], Any] | None = None
    state_specs: Any = None


def _round_body(state, r, *, inputs, spec: IterativeSpec, axis_name: str, n_shards: int,
                secure: SecureShuffleConfig | None, coalesce=None,
                trace_info: dict | None = None):
    mk, mv = spec.map_fn(state, inputs, r)
    if spec.combine_fn is not None:
        mk, mv = spec.combine_fn(mk, mv)
    n_mapped = mk.shape[0]
    capacity = spec.capacity or max(
        1, int(np.ceil(-(-n_mapped // n_shards) * resolve_capacity_factor())))
    if trace_info is not None:
        # shapes are static, so the resolved capacity is a trace-time fact;
        # the host reads it back to annotate overflow warnings
        trace_info["capacity"] = capacity
        trace_info["capacity_auto"] = not spec.capacity

    bucket = (spec.hash_fn(mk) % jnp.uint32(n_shards)).astype(jnp.int32)
    bk, bv, dropped = bucket_pack(mk, bucket, mv, n_shards, capacity)

    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure, round_index=r,
                            coalesce=coalesce)
    flat_k = recv["k"].reshape(-1)
    flat_v = compat.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]), recv["v"])
    valid = flat_k >= 0

    new_state, aux = spec.reduce_fn(state, flat_k, flat_v, valid, r)
    return new_state, (aux, lax.psum(dropped, axis_name))


def _shard_body(inputs, state, round_offset, *, spec: IterativeSpec, axis_name: str,
                n_shards: int, secure: SecureShuffleConfig | None, coalesce=None,
                trace_info: dict | None = None):
    rounds = jnp.asarray(round_offset, jnp.uint32) + jnp.arange(spec.n_rounds, dtype=jnp.uint32)
    body = partial(_round_body, inputs=inputs, spec=spec, axis_name=axis_name,
                   n_shards=n_shards, secure=secure, coalesce=coalesce,
                   trace_info=trace_info)
    final_state, (aux, dropped) = lax.scan(body, state, rounds)
    return final_state, aux, dropped


def _halting_shard_body(inputs, state, round_offset, *, spec: IterativeSpec, axis_name: str,
                        n_shards: int, secure: SecureShuffleConfig | None, loop_impl: str,
                        coalesce=None, trace_info: dict | None = None):
    """Halt-aware round loop: stops executing (and consuming keystream) once
    `spec.halt_fn` fires. Returns (state, aux, dropped, rounds_executed, halted).
    """
    n_rounds = spec.n_rounds
    body = partial(_round_body, inputs=inputs, spec=spec, axis_name=axis_name,
                   n_shards=n_shards, secure=secure, coalesce=coalesce,
                   trace_info=trace_info)
    r0 = jnp.asarray(round_offset, jnp.uint32)
    # halt_fn's replicated-only state view: sharded leaves raise on use
    state_spec_tree, flat_sharded = _resolve_state_specs(spec, state)

    # abstract round output, for the passthrough branch / preallocated
    # buffers; suppressed so the shape-only pass is invisible to wire
    # accounting (it derives no keystream and moves no bytes)
    with wire_accounting.suppressed():
        _state_sds, (aux_sds, dropped_sds) = jax.eval_shape(body, state, r0)

    def _zeros(sds_tree):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds_tree)

    def _halt(new_state, aux, r):
        guarded = _guard_state_for_halt(new_state, state_spec_tree, flat_sharded)
        return jnp.reshape(jnp.asarray(spec.halt_fn(guarded, aux, r), jnp.bool_), ())

    if loop_impl == "while":
        aux0 = jax.tree.map(lambda s: jnp.zeros((n_rounds,) + s.shape, s.dtype), aux_sds)
        dropped0 = jnp.zeros((n_rounds,) + dropped_sds.shape, dropped_sds.dtype)

        def cond(carry):
            i, _state, _aux, _dropped, halted = carry
            return jnp.logical_and(~halted, i < n_rounds)

        def w_body(carry):
            i, state, aux_buf, dropped_buf, _halted = carry
            r = r0 + i.astype(jnp.uint32)
            new_state, (aux, dropped) = body(state, r)
            aux_buf = jax.tree.map(
                lambda buf, a: lax.dynamic_update_index_in_dim(buf, a, i, 0), aux_buf, aux)
            dropped_buf = lax.dynamic_update_index_in_dim(dropped_buf, dropped, i, 0)
            return (i + 1, new_state, aux_buf, dropped_buf, _halt(new_state, aux, r))

        i, final_state, aux, dropped, halted = lax.while_loop(
            cond, w_body, (jnp.int32(0), state, aux0, dropped0, jnp.bool_(False)))
        return final_state, aux, dropped, i, halted

    def step(carry, r):
        state, halted, n_exec = carry

        def live(s):
            new_state, (aux, dropped) = body(s, r)
            return new_state, aux, dropped, _halt(new_state, aux, r)

        def skip(s):
            # no shuffle, no keystream: the halted round is a pure
            # passthrough (auditable via record_wire_bytes)
            wire_accounting.note_halted_round(secure is not None)
            return (s, _zeros(aux_sds),
                    jnp.zeros(dropped_sds.shape, dropped_sds.dtype), jnp.bool_(True))

        new_state, aux, dropped, halt = lax.cond(halted, skip, live, state)
        n_exec = n_exec + jnp.where(halted, 0, 1).astype(jnp.int32)
        return (new_state, halted | halt, n_exec), (aux, dropped)

    rounds = r0 + jnp.arange(n_rounds, dtype=jnp.uint32)
    (final_state, halted, n_exec), (aux, dropped) = lax.scan(
        step, (state, jnp.bool_(False), jnp.int32(0)), rounds)
    return final_state, aux, dropped, n_exec, halted


def make_iterative_runner(
    spec: IterativeSpec,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    donate_state: bool = False,
):
    """Build the jitted fused-round function once; call it many times.

    `chacha_impl` overrides the secure config's keystream backend
    ('pallas' | 'pallas-interpret' | 'jnp'; see `core/shuffle.py`) — baked
    in at build time, since the impl choice is part of the traced program.
    `coalesce` overrides the wire layout the same way, in BOTH modes (True —
    one packed wire through ONE all_to_all per round, plus one keystream
    launch each side in secure mode — False — the per-leaf oracle; None
    keeps the secure config's own setting / the plaintext 'auto' default).
    `loop_impl` selects the halt-aware loop shape (`HALT_LOOP_IMPLS`; only
    meaningful when `spec.halt_fn` is set).

    `donate_state=True` donates the carried-state argument's buffers to the
    dispatch (`jax.jit` donate_argnums): XLA writes the chunk's final state
    into the input's storage instead of allocating a fresh replica every
    dispatch — the natural fit for `run_until`'s chunk loop, which always
    feeds a chunk's output state into the next chunk. CALLERS OWN THE
    ALIASING CONTRACT: the state passed in is consumed (its buffers are
    deleted) and must not be reused after the call.

    Returns fn(inputs, state, round_offset=0) ->
      (final_state, aux_per_round, dropped_per_round)                  and,
      when `spec.halt_fn` is set, additionally
      (..., rounds_executed, halted)
    where aux leaves and `dropped` carry a leading (n_rounds,) dim; entries
    past `rounds_executed` are zero-filled no-op rounds. The returned
    callable exposes `.trace_info`, a dict populated at first trace with the
    resolved per-destination `capacity` (and whether it was auto-derived).

    `round_offset` is the GLOBAL index of the chunk's first round. Callers
    that dispatch the same runner repeatedly (convergence loops) MUST pass
    the running total of completed rounds: the scan executes global rounds
    offset..offset+n_rounds-1, and that global index is what map_fn /
    reduce_fn receive and what keys the per-round keystream — restarting it
    at 0 every chunk would reuse round-0's keystream across chunks (a
    two-time pad). With a halt_fn, "completed" means *executed*: halted
    rounds consume no keystream, so the next chunk resumes at
    offset + rounds_executed. It is a traced scalar: varying it never
    recompiles.
    """
    if secure is not None:
        secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
    n_shards = mesh.shape[axis_name]
    trace_info: dict = {}
    if spec.halt_fn is not None:
        loop = resolve_halt_loop(loop_impl)
        body = partial(_halting_shard_body, spec=spec, axis_name=axis_name,
                       n_shards=n_shards, secure=secure, loop_impl=loop,
                       coalesce=coalesce, trace_info=trace_info)
        extra_out = (P(), P())  # rounds_executed, halted (replicated scalars)
    else:
        body = partial(_shard_body, spec=spec, axis_name=axis_name, n_shards=n_shards,
                       secure=secure, coalesce=coalesce, trace_info=trace_info)
        extra_out = ()

    def in_specs(inputs_tree):
        return compat.tree_map(lambda _: P(axis_name), inputs_tree)

    def run(inputs, state, round_offset=0):
        # per-leaf carried-state layout (module docstring): identical spec
        # tree in and out — the driver never reshards between rounds
        state_spec_tree, _ = _resolve_state_specs(spec, state)
        fn = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(in_specs(inputs), state_spec_tree, P()),
            out_specs=(
                state_spec_tree,
                P(),
                P(),
            ) + extra_out,
            check_vma=False,
        )
        return fn(inputs, state, jnp.asarray(round_offset, jnp.uint32))

    # arg 1 is the carried state: its output has identical shapes/dtypes, so
    # donation lets XLA alias the buffers instead of re-allocating per chunk
    jitted = jax.jit(run, donate_argnums=(1,) if donate_state else ())

    def runner(inputs, state, round_offset=0):
        return jitted(inputs, state, round_offset)

    runner.trace_info = trace_info
    runner.abstract_fn = run  # un-jitted body, for make_jaxpr inspection
    runner.jitted = jitted  # exposes .lower() for donation/lowering audits
    return runner


def _warn_overflow(dropped, first_round: int, trace_info: dict | None, stacklevel: int = 3):
    """Surface per-round bucket_pack overflow with enough context to act on.

    Names every overflowing GLOBAL round index and the per-destination
    capacity that was in force (flagging when it was auto-derived), so users
    can size `IterativeSpec.capacity` without bisecting rounds.
    """
    dropped = np.asarray(dropped)
    bad = np.nonzero(dropped > 0)[0]
    if bad.size == 0:
        return
    trace_info = trace_info or {}
    cap = trace_info.get("capacity")
    cap_s = "capacity unknown (runner not yet traced)"
    if cap is not None:
        cap_s = (f"auto capacity {cap}" if trace_info.get("capacity_auto")
                 else f"capacity {cap}")
    detail = ", ".join(
        f"round {first_round + int(j)}: n_dropped={int(dropped[j])}" for j in bad)
    warnings.warn(
        f"shuffle overflow — {detail} (per-destination {cap_s}); "
        f"raise IterativeSpec.capacity to make the job lossless",
        RuntimeWarning, stacklevel=stacklevel)


def run_iterative_mapreduce(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    round_offset: int = 0,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    warn_on_overflow: bool = True,
):
    """One-shot convenience: run `spec.n_rounds` fused rounds over
    `mesh[axis_name]`. `inputs` is a pytree sharded on the leading dim;
    `init_state` is replicated carried state. `round_offset`: see
    `make_iterative_runner` — pass the count of rounds already executed
    when continuing a job across dispatches. `chacha_impl` selects the
    secure keystream backend and `coalesce` the secure wire layout (see
    `core/shuffle.py`).

    Returns (final_state, aux_per_round, dropped_per_round) — dropped has
    shape (n_rounds,) and must be all-zero for a lossless job — plus
    (rounds_executed, halted) when `spec.halt_fn` is set. Any round with
    n_dropped > 0 raises a RuntimeWarning naming the round and the capacity
    in force (`warn_on_overflow=False` to silence, e.g. when overflow is an
    expected phase of the job).
    """
    runner = make_iterative_runner(spec, mesh, axis_name, secure,
                                   chacha_impl=chacha_impl, loop_impl=loop_impl,
                                   coalesce=coalesce)
    out = runner(inputs, init_state, round_offset)
    if warn_on_overflow:
        dropped = out[2]
        n_exec = int(out[3]) if spec.halt_fn is not None else spec.n_rounds
        _warn_overflow(np.asarray(dropped)[:n_exec], round_offset, runner.trace_info)
    return out


@dataclass(frozen=True)
class RunUntilResult:
    """Outcome of a convergence-aware `run_until` job.

    state:             final carried state (device arrays, replicated) — the
                       state produced by the round that triggered the halt
                       (or the last round when the budget ran out).
    aux:               per-round aux pytree, leaves stacked over the
                       `rounds_executed` EXECUTED rounds only (numpy);
                       masked no-op rounds are trimmed.
    dropped:           (rounds_executed,) overflow counts per executed round.
    rounds_executed:   rounds whose body actually ran (== keystream rounds
                       consumed in secure mode).
    rounds_dispatched: rounds the host shipped to the device across all
                       chunks (>= rounds_executed; the gap is the masked
                       no-op tail of the halting chunk).
    n_dispatches:      host->device round trips.
    halted:            True when halt_fn fired; False when `max_rounds` was
                       exhausted first.
    """

    state: Any
    aux: Any
    dropped: Any
    rounds_executed: int
    rounds_dispatched: int
    n_dispatches: int
    halted: bool


def run_until(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    secure: SecureShuffleConfig | None = None,
    max_rounds: int = 64,
    round_offset: int = 0,
    min_chunk: int = 1,
    growth="auto",
    max_chunk: int | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    donate_state: bool = True,
    runners=None,
    warn_on_overflow: bool = True,
    job_tag=None,
) -> RunUntilResult:
    """Run a job until `spec.halt_fn` fires or `max_rounds` rounds executed.

    The convergence-aware twin of `run_iterative_mapreduce`: rounds are
    dispatched in adaptively sized chunks — `min_chunk` rounds first, then
    ×`growth` per dispatch up to `max_chunk` (default `max_rounds`;
    `growth` 'auto' resolves through `resolve_chunk_growth`) — and
    each chunk's fused round loop early-exits on device the moment
    `halt_fn` fires (module docstring: Termination). A job converging in 7
    rounds therefore neither compiles nor dispatches a 32-round program,
    and pays for no post-convergence rounds beyond the masked no-op tail of
    its final chunk.

    The global round index — and with it the secure keystream space — is
    threaded across chunks automatically: chunk i+1's round_offset is
    `round_offset` + total rounds *executed* so far, which is exactly the
    keystream-disjointness contract (halted rounds consume none).

    `spec.n_rounds` is ignored (chunk sizes are chosen here). A spec
    without `halt_fn` is allowed: the job simply runs all `max_rounds`
    rounds (useful to share this entry point across workloads).

    `donate_state` (default True) donates each dispatch's carried-state
    buffers: the chunk loop always feeds a chunk's output state into the
    next chunk, so XLA can write the new state into the old one's storage
    instead of re-allocating it every dispatch. The caller's `init_state`
    is protected by ONE defensive device copy up front (donation would
    otherwise delete the caller's buffers on the first chunk); every
    subsequent dispatch re-uses storage with zero copies.

    `runners`: optional mutable runner cache reused across calls to amortize
    XLA compiles — a plain dict mapping chunk size -> runner, or any object
    with `get_or_build(n_rounds, build_fn) -> runner` (the serving path's
    keyed `RunnerCache` views; module docstring: Serving). Callers own its
    validity: it must have been populated with the SAME spec (sans
    n_rounds) / mesh / secure / impl / donation arguments.

    `job_tag`: optional job id under which the job's traced shuffles are
    recorded (`wire_accounting.tagged`), so interleaved jobs sharing a
    `record_wire_bytes` sink stay separable.
    """
    gen = run_until_chunks(
        spec, inputs, init_state, mesh, axis_name, secure=secure,
        max_rounds=max_rounds, round_offset=round_offset, min_chunk=min_chunk,
        growth=growth, max_chunk=max_chunk, chacha_impl=chacha_impl,
        loop_impl=loop_impl, coalesce=coalesce, donate_state=donate_state,
        runners=runners, warn_on_overflow=warn_on_overflow, job_tag=job_tag)
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def run_until_chunks(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    secure: SecureShuffleConfig | None = None,
    max_rounds: int = 64,
    round_offset: int = 0,
    min_chunk: int = 1,
    growth="auto",
    max_chunk: int | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    donate_state: bool = True,
    runners=None,
    warn_on_overflow: bool = True,
    job_tag=None,
):
    """Cooperative (generator) form of `run_until` — same arguments.

    Yields a progress dict after every chunk dispatch ({"chunk_rounds",
    "rounds_executed", "n_dispatches", "halted"}) and RETURNS the final
    `RunUntilResult` as the generator's `StopIteration.value`. A host
    scheduler (the serving admission loop) drives many jobs' generators
    round-robin, one chunk per turn, on a single dispatch thread; each
    suspended generator keeps its own carried state and global round
    offset, so interleaving any number of jobs is bit-identical to running
    them serially.

    The shuffle-overflow warning is emitted ONCE per job, after the last
    chunk, summarizing every overflowing GLOBAL round index — not once per
    dispatched chunk — so a long queued job cannot flood the log.
    """
    if max_rounds < 1:
        raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
    growth = resolve_chunk_growth(growth, min_chunk=min_chunk,
                                  max_rounds=max_rounds, max_chunk=max_chunk)
    if min_chunk < 1 or growth < 1:
        raise ValueError(f"min_chunk and growth must be >= 1, got {min_chunk}, {growth}")
    max_chunk = min(max_chunk or max_rounds, max_rounds)
    runners = {} if runners is None else runners
    # duck-typed cache: the serving RunnerCache view, or the legacy dict
    get_or_build = getattr(runners, "get_or_build", None)

    state = init_state
    if donate_state:
        # one up-front copy shields the caller's init_state buffers from the
        # first chunk's donation; all later chunks donate run_until's own
        # output state, which nothing else holds
        state = jax.tree.map(lambda x: jnp.array(x, copy=True), init_state)
    executed = dispatched = n_dispatches = 0
    halted = False
    aux_chunks: list = []
    dropped_chunks: list = []
    overflow_trace_info: dict | None = None
    chunk = min(max(1, min_chunk), max_chunk)
    while executed < max_rounds and not halted:
        n = min(chunk, max_rounds - executed)

        def build(n=n):
            return make_iterative_runner(
                replace(spec, n_rounds=n), mesh, axis_name, secure,
                chacha_impl=chacha_impl, loop_impl=loop_impl,
                coalesce=coalesce, donate_state=donate_state)

        if get_or_build is not None:
            runner = get_or_build(n, build)
        else:
            runner = runners.get(n)
            if runner is None:
                runner = runners[n] = build()
        with wire_accounting.tagged(job_tag):
            out = runner(inputs, state, round_offset + executed)
        if spec.halt_fn is None:
            state, aux, dropped = out
            n_exec, chunk_halted = n, False
        else:
            state, aux, dropped, n_exec, chunk_halted = out
            n_exec, chunk_halted = int(n_exec), bool(chunk_halted)
        n_dispatches += 1
        dispatched += n
        aux_chunks.append(jax.tree.map(lambda a: np.asarray(a)[:n_exec], aux))
        dropped_chunks.append(np.asarray(dropped)[:n_exec])
        if warn_on_overflow and overflow_trace_info is None and np.any(
                dropped_chunks[-1] > 0):
            overflow_trace_info = dict(runner.trace_info)
        executed += n_exec
        halted = chunk_halted
        chunk = min(chunk * growth, max_chunk)
        yield {"chunk_rounds": n, "rounds_executed": executed,
               "n_dispatches": n_dispatches, "halted": halted}

    aux = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *aux_chunks)
    dropped = np.concatenate(dropped_chunks) if dropped_chunks else np.zeros((0,), np.int32)
    if warn_on_overflow and overflow_trace_info is not None:
        # ONE summary warning per job: executed rounds are gapless from
        # round_offset, so the concatenated per-round drops carry every
        # overflowing GLOBAL index (capacity from the chunk that overflowed)
        _warn_overflow(dropped, round_offset, overflow_trace_info, stacklevel=4)
    return RunUntilResult(
        state=state,
        aux=aux,
        dropped=dropped,
        rounds_executed=executed,
        rounds_dispatched=dispatched,
        n_dispatches=n_dispatches,
        halted=halted,
    )

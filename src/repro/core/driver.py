"""Iterative secure MapReduce driver: N rounds inside ONE jitted dispatch.

Why
---
The paper's headline workload — k-means — is an *iterative* MapReduce job,
yet `repro.core.engine.run_mapreduce` executes exactly one
map→shuffle→reduce round per dispatch, so every iteration pays a host
round-trip, fresh argument transfers, and (in secure mode) re-derived
keystream setup. SGX-MR (arXiv:2009.03518) makes the same observation for
enclaves: regulating the whole dataflow inside the trusted boundary, not
per-round hops through untrusted orchestration, is what keeps overhead low.
This driver runs the full round loop as a single `lax.scan` under
`shard_map`, so a converged k-means run costs O(n_rounds / rounds_per_dispatch)
host round-trips instead of O(n_rounds).

Round structure
---------------
Each round r of `run_iterative_mapreduce` executes, per shard:

    mapped_k, mapped_v = spec.map_fn(state, inputs, r)      # "mapper enclave"
    [mapped_k, mapped_v = spec.combine_fn(mapped_k, mapped_v)]
    bucket  = spec.hash_fn(mapped_k) % R
    send    = bucket_pack(...)                              # fixed (R, C, ...)
    recv    = keyed_all_to_all(send, axis, secure, round_index=r)
    state, aux = spec.reduce_fn(state, keys, values, valid, r)   # "reducer"

and the scan threads `state` (e.g. k-means centroids) into the next round.
Per-round aux (stacked over rounds) and per-round overflow counts
(`n_dropped`, psum'd over shards) come back to the host so convergence can
be judged — and a mid-chunk convergence point recovered from aux — without
re-entering the device loop.

Carried-state contract
----------------------
`state` is REPLICATED: every shard holds the same value on entry, and
`reduce_fn` must restore replication before returning (end in a collective —
psum / all_gather — exactly like the paper's "client redistributes the new
centers" step). The driver shards `inputs` over the mesh axis and replicates
`state`/`aux` (out_specs `P()`); a reduce_fn that returns shard-varying
state is a bug the shuffle cannot fix.

Counter-space layout (extends core/shuffle.py)
----------------------------------------------
A multi-round job performs many encrypted shuffles under one session key.
The per-shuffle layout (nonce word 0 ^= source index, counter = ctr0 +
leaf_offset + dest_row·blocks_per_row) is unchanged; the driver additionally
XORs the round index into nonce word 1 via
`keyed_all_to_all(..., round_index=r)`. The keystream spaces of distinct
rounds are therefore disjoint by construction — reusing one (as the
per-round Python loop historically did, re-dispatching with an identical
nonce/counter every iteration) is a two-time pad. The round index is part
of the replicated loop state; both endpoints derive the keystream locally
and nothing about it crosses the wire.

The index is GLOBAL across dispatches: a convergence loop that calls the
same runner in chunks passes `round_offset` = rounds already executed, so
chunk 2 continues at round n_rounds, not back at round 0 (which would
reuse chunk 1's keystreams). `kmeans_fit` threads its iteration counter
through exactly this way.

Workloads on the driver: `repro.core.kmeans` (paper §V), `repro.core.sort`
(TeraSort-style sampling sort with splitter refinement), `repro.core.grep`
(multi-round streaming grep).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.engine import default_hash
from repro.core.shuffle import SecureShuffleConfig, bucket_pack, keyed_all_to_all


@dataclass(frozen=True)
class IterativeSpec:
    """A multi-round MapReduce job over fixed-shape shards.

    map_fn(state, inputs, round_index) -> (mapped_keys, mapped_values)
        Per-shard, vectorized. `inputs` is the (local slice of the) sharded
        input pytree; `round_index` is a traced u32 scalar for round-varying
        behavior (streaming slices, phase switches).
    combine_fn(keys, values) -> (keys, values)
        Optional local pre-aggregation before the shuffle.
    reduce_fn(state, keys, values, valid, round_index) -> (new_state, aux)
        Per-shard over the received pairs; must restore state replication
        (end in psum/all_gather). `aux` is any pytree of per-round
        diagnostics (stacked over rounds by the scan).
    hash_fn(keys) -> u32
        destination shard = hash_fn(k) % R.
    capacity:  per-destination slots C; 0 -> auto (ceil(n_mapped / R) * 2).
    n_rounds:  rounds fused into one dispatch.
    """

    map_fn: Callable[[Any, Any, Any], tuple]
    reduce_fn: Callable[[Any, Any, Any, Any, Any], tuple]
    combine_fn: Callable[[Any, Any], tuple] | None = None
    hash_fn: Callable = default_hash
    capacity: int = 0
    n_rounds: int = 1


def _round_body(state, r, *, inputs, spec: IterativeSpec, axis_name: str, n_shards: int,
                secure: SecureShuffleConfig | None):
    mk, mv = spec.map_fn(state, inputs, r)
    if spec.combine_fn is not None:
        mk, mv = spec.combine_fn(mk, mv)
    n_mapped = mk.shape[0]
    capacity = spec.capacity or max(1, -(-n_mapped // n_shards) * 2)

    bucket = (spec.hash_fn(mk) % jnp.uint32(n_shards)).astype(jnp.int32)
    bk, bv, dropped = bucket_pack(mk, bucket, mv, n_shards, capacity)

    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure, round_index=r)
    flat_k = recv["k"].reshape(-1)
    flat_v = compat.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]), recv["v"])
    valid = flat_k >= 0

    new_state, aux = spec.reduce_fn(state, flat_k, flat_v, valid, r)
    return new_state, (aux, lax.psum(dropped, axis_name))


def _shard_body(inputs, state, round_offset, *, spec: IterativeSpec, axis_name: str,
                n_shards: int, secure: SecureShuffleConfig | None):
    rounds = jnp.asarray(round_offset, jnp.uint32) + jnp.arange(spec.n_rounds, dtype=jnp.uint32)
    body = partial(_round_body, inputs=inputs, spec=spec, axis_name=axis_name,
                   n_shards=n_shards, secure=secure)
    final_state, (aux, dropped) = lax.scan(body, state, rounds)
    return final_state, aux, dropped


def make_iterative_runner(
    spec: IterativeSpec,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    chacha_impl: str | None = None,
):
    """Build the jitted fused-round function once; call it many times.

    `chacha_impl` overrides the secure config's keystream backend
    ('pallas' | 'pallas-interpret' | 'jnp'; see `core/shuffle.py`) — baked
    in at build time, since the impl choice is part of the traced program.

    Returns fn(inputs, state, round_offset=0) ->
    (final_state, aux_per_round, dropped_per_round) where aux leaves and
    `dropped` carry a leading (n_rounds,) dim.

    `round_offset` is the GLOBAL index of the chunk's first round. Callers
    that dispatch the same runner repeatedly (convergence loops) MUST pass
    the running total of completed rounds: the scan executes global rounds
    offset..offset+n_rounds-1, and that global index is what map_fn /
    reduce_fn receive and what keys the per-round keystream — restarting it
    at 0 every chunk would reuse round-0's keystream across chunks (a
    two-time pad). It is a traced scalar: varying it never recompiles.
    """
    if secure is not None:
        secure = secure.with_impl(chacha_impl)
    n_shards = mesh.shape[axis_name]
    body = partial(_shard_body, spec=spec, axis_name=axis_name, n_shards=n_shards,
                   secure=secure)

    def in_specs(inputs_tree):
        return compat.tree_map(lambda _: P(axis_name), inputs_tree)

    def run(inputs, state, round_offset=0):
        fn = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(in_specs(inputs), compat.tree_map(lambda _: P(), state), P()),
            out_specs=(
                compat.tree_map(lambda _: P(), state),
                P(),
                P(),
            ),
            check_vma=False,
        )
        return fn(inputs, state, jnp.asarray(round_offset, jnp.uint32))

    return jax.jit(run)


def run_iterative_mapreduce(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    round_offset: int = 0,
    chacha_impl: str | None = None,
):
    """One-shot convenience: run `spec.n_rounds` fused rounds over
    `mesh[axis_name]`. `inputs` is a pytree sharded on the leading dim;
    `init_state` is replicated carried state. `round_offset`: see
    `make_iterative_runner` — pass the count of rounds already executed
    when continuing a job across dispatches. `chacha_impl` selects the
    secure keystream backend (see `core/shuffle.py`).

    Returns (final_state, aux_per_round, dropped_per_round) — dropped has
    shape (n_rounds,) and must be all-zero for a lossless job.
    """
    runner = make_iterative_runner(spec, mesh, axis_name, secure, chacha_impl=chacha_impl)
    return runner(inputs, init_state, round_offset)

"""Iterative secure MapReduce driver: N rounds inside ONE jitted dispatch.

Why
---
The paper's headline workload — k-means — is an *iterative* MapReduce job,
yet `repro.core.engine.run_mapreduce` executes exactly one
map→shuffle→reduce round per dispatch, so every iteration pays a host
round-trip, fresh argument transfers, and (in secure mode) re-derived
keystream setup. SGX-MR (arXiv:2009.03518) makes the same observation for
enclaves: regulating the whole dataflow inside the trusted boundary, not
per-round hops through untrusted orchestration, is what keeps overhead low.
This driver runs the full round loop as a single `lax.scan` under
`shard_map`, so a converged k-means run costs O(n_rounds / rounds_per_dispatch)
host round-trips instead of O(n_rounds).

Round structure
---------------
Each round r of `run_iterative_mapreduce` executes, per shard:

    mapped_k, mapped_v = spec.map_fn(state, inputs, r)      # "mapper enclave"
    [mapped_k, mapped_v = spec.combine_fn(mapped_k, mapped_v)]
    bucket  = spec.hash_fn(mapped_k) % R
    send    = bucket_pack(...)                              # fixed (R, C, ...)
    recv    = keyed_all_to_all(send, axis, secure, round_index=r)
    state, aux = spec.reduce_fn(state, keys, values, valid, r)   # "reducer"

and the scan threads `state` (e.g. k-means centroids) into the next round.
Per-round aux (stacked over rounds) and per-round overflow counts
(`n_dropped`, psum'd over shards) come back to the host so convergence can
be judged — and a mid-chunk convergence point recovered from aux — without
re-entering the device loop.

Termination
-----------
Fixed `n_rounds` is the wrong contract for convergence-driven jobs: after
the centroids stop moving, every remaining round in the chunk still pays the
full map → bucket_pack → encrypt → all_to_all → decrypt → reduce pipeline.
`IterativeSpec.halt_fn(state, aux, round_index) -> bool` moves the
termination decision on-device, and `run_until` stops paying for
post-convergence rounds at two levels:

  * ON-DEVICE the round loop is halt-aware. `halt_fn` is evaluated right
    after each round's reduce, on the freshly reduced (replicated) state and
    that round's aux; once it returns True the remaining rounds of the chunk
    become no-ops. Two interchangeable loop shapes implement this (select
    with `loop_impl`, default `DEFAULT_HALT_LOOP` = 'while'):
      - 'while'      — a `lax.while_loop` whose predicate is
        `~halted & (i < n_rounds)`, writing aux into preallocated buffers;
      - 'masked_scan' — the fixed-length `lax.scan` is kept, but a
        `lax.cond` gates the whole round body into a cheap passthrough
        (state unchanged, zero aux, no shuffle) once halted.
    Both return `(state, aux, dropped, rounds_executed, halted)` and are
    bit-identical; `benchmarks/bench_iteration_time.py` measures both (the
    while loop compiles ~2x faster and skips the masked tail entirely,
    hence the default; see the note at `DEFAULT_HALT_LOOP`).

    REPLICATED-HALT CONTRACT: `halt_fn` must be a pure function of
    replicated values (the carried state — which `reduce_fn` must replicate
    before returning — the aux derived from it, and the round index). All
    shards then compute the same predicate by construction, so the
    collectives inside `lax.cond` / `lax.while_loop` branch uniformly
    across the mesh. A halt decision derived from shard-local data is a
    deadlock (shards disagree about whether the all_to_all happens).

  * KEYSTREAM ACCOUNTING FOR HALTED ROUNDS: a halted round consumes NO
    keystream — the passthrough branch performs no encryption and no
    collective (`record_wire_bytes` shows zero bytes for it). The global
    round index keeps advancing per *executed* round only: `run_until`
    feeds each chunk's returned `rounds_executed` into the next chunk's
    `round_offset`, so executed rounds worldwide occupy the disjoint,
    gapless counter range [round_offset, round_offset + total_executed).
    Round indices skipped by a halted chunk tail were never used to derive
    keystream, so re-issuing them to the next chunk cannot reuse a pad.

  * ON THE HOST `run_until` dispatches adaptively sized chunks: starting at
    `min_chunk` rounds and growing geometrically (×`growth`, capped at
    `max_chunk`), so a job converging in 7 rounds never dispatches — or
    compiles — a 32-round program, while long jobs still amortize host
    round-trips at the full chunk size.

Carried-state contract
----------------------
`state` is REPLICATED: every shard holds the same value on entry, and
`reduce_fn` must restore replication before returning (end in a collective —
psum / all_gather — exactly like the paper's "client redistributes the new
centers" step). The driver shards `inputs` over the mesh axis and replicates
`state`/`aux` (out_specs `P()`); a reduce_fn that returns shard-varying
state is a bug the shuffle cannot fix.

Counter-space layout (extends core/shuffle.py)
----------------------------------------------
A multi-round job performs many encrypted shuffles under one session key.
The per-shuffle layout (nonce word 0 ^= source index, counter = ctr0 +
leaf_offset + dest_row·blocks_per_row) is unchanged; the driver additionally
XORs the round index into nonce word 1 via
`keyed_all_to_all(..., round_index=r)`. The keystream spaces of distinct
rounds are therefore disjoint by construction — reusing one (as the
per-round Python loop historically did, re-dispatching with an identical
nonce/counter every iteration) is a two-time pad. The round index is part
of the replicated loop state; both endpoints derive the keystream locally
and nothing about it crosses the wire.

The index is GLOBAL across dispatches: a convergence loop that calls the
same runner in chunks passes `round_offset` = rounds already executed, so
chunk 2 continues at round n_rounds, not back at round 0 (which would
reuse chunk 1's keystreams). `run_until` does exactly this with each
chunk's `rounds_executed`; `kmeans_fit` and the other convergence loops
inherit the contract by running on it.

Workloads on the driver: `repro.core.kmeans` (paper §V), `repro.core.sort`
(TeraSort-style sampling sort with splitter refinement), `repro.core.grep`
(multi-round streaming grep) — all three terminate through `run_until`.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.engine import default_hash
from repro.core.shuffle import (
    SecureShuffleConfig,
    bucket_pack,
    keyed_all_to_all,
    wire_accounting,
)

HALT_LOOP_IMPLS = ("masked_scan", "while")
# Measured on CPU with the pallas-interpret keystream
# (benchmarks/bench_iteration_time.py, secure k-means, 8-round chunk
# converging at round 5): 'while' compiles ~2x faster (34s vs 67s — the
# cond-gated scan traces the round body into an extra conditional branch)
# and is ~13% faster per executed round at steady state (it exits the loop
# instead of running the masked no-op tail), so it is the default.
# 'masked_scan' is the documented loser but is kept: its traced skip branch
# is what makes the zero-bytes-for-halted-rounds claim auditable via
# `record_wire_bytes`, and its aux layout matches the non-halting scan.
DEFAULT_HALT_LOOP = "while"


@dataclass(frozen=True)
class IterativeSpec:
    """A multi-round MapReduce job over fixed-shape shards.

    map_fn(state, inputs, round_index) -> (mapped_keys, mapped_values)
        Per-shard, vectorized. `inputs` is the (local slice of the) sharded
        input pytree; `round_index` is a traced u32 scalar for round-varying
        behavior (streaming slices, phase switches).
    combine_fn(keys, values) -> (keys, values)
        Optional local pre-aggregation before the shuffle.
    reduce_fn(state, keys, values, valid, round_index) -> (new_state, aux)
        Per-shard over the received pairs; must restore state replication
        (end in psum/all_gather). `aux` is any pytree of per-round
        diagnostics (stacked over rounds by the scan).
    hash_fn(keys) -> u32
        destination shard = hash_fn(k) % R.
    capacity:  per-destination slots C; 0 -> auto (ceil(n_mapped / R) * 2).
    n_rounds:  rounds fused into one dispatch.
    halt_fn(state, aux, round_index) -> bool scalar  [optional]
        Convergence predicate, evaluated after every round on that round's
        freshly reduced state/aux. MUST depend only on replicated values so
        every shard agrees (module docstring: Termination). When set, the
        fused loop stops executing rounds — and consuming keystream — as
        soon as it returns True; runners then also return
        (rounds_executed, halted).
    """

    map_fn: Callable[[Any, Any, Any], tuple]
    reduce_fn: Callable[[Any, Any, Any, Any, Any], tuple]
    combine_fn: Callable[[Any, Any], tuple] | None = None
    hash_fn: Callable = default_hash
    capacity: int = 0
    n_rounds: int = 1
    halt_fn: Callable[[Any, Any, Any], Any] | None = None


def _round_body(state, r, *, inputs, spec: IterativeSpec, axis_name: str, n_shards: int,
                secure: SecureShuffleConfig | None, trace_info: dict | None = None):
    mk, mv = spec.map_fn(state, inputs, r)
    if spec.combine_fn is not None:
        mk, mv = spec.combine_fn(mk, mv)
    n_mapped = mk.shape[0]
    capacity = spec.capacity or max(1, -(-n_mapped // n_shards) * 2)
    if trace_info is not None:
        # shapes are static, so the resolved capacity is a trace-time fact;
        # the host reads it back to annotate overflow warnings
        trace_info["capacity"] = capacity
        trace_info["capacity_auto"] = not spec.capacity

    bucket = (spec.hash_fn(mk) % jnp.uint32(n_shards)).astype(jnp.int32)
    bk, bv, dropped = bucket_pack(mk, bucket, mv, n_shards, capacity)

    recv = keyed_all_to_all({"k": bk, "v": bv}, axis_name, secure, round_index=r)
    flat_k = recv["k"].reshape(-1)
    flat_v = compat.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]), recv["v"])
    valid = flat_k >= 0

    new_state, aux = spec.reduce_fn(state, flat_k, flat_v, valid, r)
    return new_state, (aux, lax.psum(dropped, axis_name))


def _shard_body(inputs, state, round_offset, *, spec: IterativeSpec, axis_name: str,
                n_shards: int, secure: SecureShuffleConfig | None,
                trace_info: dict | None = None):
    rounds = jnp.asarray(round_offset, jnp.uint32) + jnp.arange(spec.n_rounds, dtype=jnp.uint32)
    body = partial(_round_body, inputs=inputs, spec=spec, axis_name=axis_name,
                   n_shards=n_shards, secure=secure, trace_info=trace_info)
    final_state, (aux, dropped) = lax.scan(body, state, rounds)
    return final_state, aux, dropped


def _halting_shard_body(inputs, state, round_offset, *, spec: IterativeSpec, axis_name: str,
                        n_shards: int, secure: SecureShuffleConfig | None, loop_impl: str,
                        trace_info: dict | None = None):
    """Halt-aware round loop: stops executing (and consuming keystream) once
    `spec.halt_fn` fires. Returns (state, aux, dropped, rounds_executed, halted).
    """
    n_rounds = spec.n_rounds
    body = partial(_round_body, inputs=inputs, spec=spec, axis_name=axis_name,
                   n_shards=n_shards, secure=secure, trace_info=trace_info)
    r0 = jnp.asarray(round_offset, jnp.uint32)

    # abstract round output, for the passthrough branch / preallocated
    # buffers; suppressed so the shape-only pass is invisible to wire
    # accounting (it derives no keystream and moves no bytes)
    with wire_accounting.suppressed():
        _state_sds, (aux_sds, dropped_sds) = jax.eval_shape(body, state, r0)

    def _zeros(sds_tree):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds_tree)

    def _halt(new_state, aux, r):
        return jnp.reshape(jnp.asarray(spec.halt_fn(new_state, aux, r), jnp.bool_), ())

    if loop_impl == "while":
        aux0 = jax.tree.map(lambda s: jnp.zeros((n_rounds,) + s.shape, s.dtype), aux_sds)
        dropped0 = jnp.zeros((n_rounds,) + dropped_sds.shape, dropped_sds.dtype)

        def cond(carry):
            i, _state, _aux, _dropped, halted = carry
            return jnp.logical_and(~halted, i < n_rounds)

        def w_body(carry):
            i, state, aux_buf, dropped_buf, _halted = carry
            r = r0 + i.astype(jnp.uint32)
            new_state, (aux, dropped) = body(state, r)
            aux_buf = jax.tree.map(
                lambda buf, a: lax.dynamic_update_index_in_dim(buf, a, i, 0), aux_buf, aux)
            dropped_buf = lax.dynamic_update_index_in_dim(dropped_buf, dropped, i, 0)
            return (i + 1, new_state, aux_buf, dropped_buf, _halt(new_state, aux, r))

        i, final_state, aux, dropped, halted = lax.while_loop(
            cond, w_body, (jnp.int32(0), state, aux0, dropped0, jnp.bool_(False)))
        return final_state, aux, dropped, i, halted

    def step(carry, r):
        state, halted, n_exec = carry

        def live(s):
            new_state, (aux, dropped) = body(s, r)
            return new_state, aux, dropped, _halt(new_state, aux, r)

        def skip(s):
            # no shuffle, no keystream: the halted round is a pure
            # passthrough (auditable via record_wire_bytes)
            wire_accounting.note_halted_round(secure is not None)
            return (s, _zeros(aux_sds),
                    jnp.zeros(dropped_sds.shape, dropped_sds.dtype), jnp.bool_(True))

        new_state, aux, dropped, halt = lax.cond(halted, skip, live, state)
        n_exec = n_exec + jnp.where(halted, 0, 1).astype(jnp.int32)
        return (new_state, halted | halt, n_exec), (aux, dropped)

    rounds = r0 + jnp.arange(n_rounds, dtype=jnp.uint32)
    (final_state, halted, n_exec), (aux, dropped) = lax.scan(
        step, (state, jnp.bool_(False), jnp.int32(0)), rounds)
    return final_state, aux, dropped, n_exec, halted


def make_iterative_runner(
    spec: IterativeSpec,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    donate_state: bool = False,
):
    """Build the jitted fused-round function once; call it many times.

    `chacha_impl` overrides the secure config's keystream backend
    ('pallas' | 'pallas-interpret' | 'jnp'; see `core/shuffle.py`) — baked
    in at build time, since the impl choice is part of the traced program.
    `coalesce` overrides the secure wire layout the same way (True — one
    keystream launch each side of ONE all_to_all per round — False — the
    per-leaf oracle; None keeps the config's own setting). `loop_impl` selects the
    halt-aware loop shape (`HALT_LOOP_IMPLS`; only meaningful when
    `spec.halt_fn` is set).

    `donate_state=True` donates the carried-state argument's buffers to the
    dispatch (`jax.jit` donate_argnums): XLA writes the chunk's final state
    into the input's storage instead of allocating a fresh replica every
    dispatch — the natural fit for `run_until`'s chunk loop, which always
    feeds a chunk's output state into the next chunk. CALLERS OWN THE
    ALIASING CONTRACT: the state passed in is consumed (its buffers are
    deleted) and must not be reused after the call.

    Returns fn(inputs, state, round_offset=0) ->
      (final_state, aux_per_round, dropped_per_round)                  and,
      when `spec.halt_fn` is set, additionally
      (..., rounds_executed, halted)
    where aux leaves and `dropped` carry a leading (n_rounds,) dim; entries
    past `rounds_executed` are zero-filled no-op rounds. The returned
    callable exposes `.trace_info`, a dict populated at first trace with the
    resolved per-destination `capacity` (and whether it was auto-derived).

    `round_offset` is the GLOBAL index of the chunk's first round. Callers
    that dispatch the same runner repeatedly (convergence loops) MUST pass
    the running total of completed rounds: the scan executes global rounds
    offset..offset+n_rounds-1, and that global index is what map_fn /
    reduce_fn receive and what keys the per-round keystream — restarting it
    at 0 every chunk would reuse round-0's keystream across chunks (a
    two-time pad). With a halt_fn, "completed" means *executed*: halted
    rounds consume no keystream, so the next chunk resumes at
    offset + rounds_executed. It is a traced scalar: varying it never
    recompiles.
    """
    if secure is not None:
        secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
    n_shards = mesh.shape[axis_name]
    trace_info: dict = {}
    if spec.halt_fn is not None:
        loop = loop_impl or DEFAULT_HALT_LOOP
        if loop not in HALT_LOOP_IMPLS:
            raise ValueError(f"loop_impl must be one of {HALT_LOOP_IMPLS}, got {loop!r}")
        body = partial(_halting_shard_body, spec=spec, axis_name=axis_name,
                       n_shards=n_shards, secure=secure, loop_impl=loop,
                       trace_info=trace_info)
        extra_out = (P(), P())  # rounds_executed, halted (replicated scalars)
    else:
        body = partial(_shard_body, spec=spec, axis_name=axis_name, n_shards=n_shards,
                       secure=secure, trace_info=trace_info)
        extra_out = ()

    def in_specs(inputs_tree):
        return compat.tree_map(lambda _: P(axis_name), inputs_tree)

    def run(inputs, state, round_offset=0):
        fn = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(in_specs(inputs), compat.tree_map(lambda _: P(), state), P()),
            out_specs=(
                compat.tree_map(lambda _: P(), state),
                P(),
                P(),
            ) + extra_out,
            check_vma=False,
        )
        return fn(inputs, state, jnp.asarray(round_offset, jnp.uint32))

    # arg 1 is the carried state: its output has identical shapes/dtypes, so
    # donation lets XLA alias the buffers instead of re-allocating per chunk
    jitted = jax.jit(run, donate_argnums=(1,) if donate_state else ())

    def runner(inputs, state, round_offset=0):
        return jitted(inputs, state, round_offset)

    runner.trace_info = trace_info
    runner.abstract_fn = run  # un-jitted body, for make_jaxpr inspection
    runner.jitted = jitted  # exposes .lower() for donation/lowering audits
    return runner


def _warn_overflow(dropped, first_round: int, trace_info: dict | None, stacklevel: int = 3):
    """Surface per-round bucket_pack overflow with enough context to act on.

    Names every overflowing GLOBAL round index and the per-destination
    capacity that was in force (flagging when it was auto-derived), so users
    can size `IterativeSpec.capacity` without bisecting rounds.
    """
    dropped = np.asarray(dropped)
    bad = np.nonzero(dropped > 0)[0]
    if bad.size == 0:
        return
    trace_info = trace_info or {}
    cap = trace_info.get("capacity")
    cap_s = "capacity unknown (runner not yet traced)"
    if cap is not None:
        cap_s = (f"auto capacity {cap}" if trace_info.get("capacity_auto")
                 else f"capacity {cap}")
    detail = ", ".join(
        f"round {first_round + int(j)}: n_dropped={int(dropped[j])}" for j in bad)
    warnings.warn(
        f"shuffle overflow — {detail} (per-destination {cap_s}); "
        f"raise IterativeSpec.capacity to make the job lossless",
        RuntimeWarning, stacklevel=stacklevel)


def run_iterative_mapreduce(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    secure: SecureShuffleConfig | None = None,
    round_offset: int = 0,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    warn_on_overflow: bool = True,
):
    """One-shot convenience: run `spec.n_rounds` fused rounds over
    `mesh[axis_name]`. `inputs` is a pytree sharded on the leading dim;
    `init_state` is replicated carried state. `round_offset`: see
    `make_iterative_runner` — pass the count of rounds already executed
    when continuing a job across dispatches. `chacha_impl` selects the
    secure keystream backend and `coalesce` the secure wire layout (see
    `core/shuffle.py`).

    Returns (final_state, aux_per_round, dropped_per_round) — dropped has
    shape (n_rounds,) and must be all-zero for a lossless job — plus
    (rounds_executed, halted) when `spec.halt_fn` is set. Any round with
    n_dropped > 0 raises a RuntimeWarning naming the round and the capacity
    in force (`warn_on_overflow=False` to silence, e.g. when overflow is an
    expected phase of the job).
    """
    runner = make_iterative_runner(spec, mesh, axis_name, secure,
                                   chacha_impl=chacha_impl, loop_impl=loop_impl,
                                   coalesce=coalesce)
    out = runner(inputs, init_state, round_offset)
    if warn_on_overflow:
        dropped = out[2]
        n_exec = int(out[3]) if spec.halt_fn is not None else spec.n_rounds
        _warn_overflow(np.asarray(dropped)[:n_exec], round_offset, runner.trace_info)
    return out


@dataclass(frozen=True)
class RunUntilResult:
    """Outcome of a convergence-aware `run_until` job.

    state:             final carried state (device arrays, replicated) — the
                       state produced by the round that triggered the halt
                       (or the last round when the budget ran out).
    aux:               per-round aux pytree, leaves stacked over the
                       `rounds_executed` EXECUTED rounds only (numpy);
                       masked no-op rounds are trimmed.
    dropped:           (rounds_executed,) overflow counts per executed round.
    rounds_executed:   rounds whose body actually ran (== keystream rounds
                       consumed in secure mode).
    rounds_dispatched: rounds the host shipped to the device across all
                       chunks (>= rounds_executed; the gap is the masked
                       no-op tail of the halting chunk).
    n_dispatches:      host->device round trips.
    halted:            True when halt_fn fired; False when `max_rounds` was
                       exhausted first.
    """

    state: Any
    aux: Any
    dropped: Any
    rounds_executed: int
    rounds_dispatched: int
    n_dispatches: int
    halted: bool


def run_until(
    spec: IterativeSpec,
    inputs,
    init_state,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    secure: SecureShuffleConfig | None = None,
    max_rounds: int = 64,
    round_offset: int = 0,
    min_chunk: int = 1,
    growth: int = 2,
    max_chunk: int | None = None,
    chacha_impl: str | None = None,
    loop_impl: str | None = None,
    coalesce: bool | None = None,
    donate_state: bool = True,
    runners: dict | None = None,
    warn_on_overflow: bool = True,
) -> RunUntilResult:
    """Run a job until `spec.halt_fn` fires or `max_rounds` rounds executed.

    The convergence-aware twin of `run_iterative_mapreduce`: rounds are
    dispatched in adaptively sized chunks — `min_chunk` rounds first, then
    ×`growth` per dispatch up to `max_chunk` (default `max_rounds`) — and
    each chunk's fused round loop early-exits on device the moment
    `halt_fn` fires (module docstring: Termination). A job converging in 7
    rounds therefore neither compiles nor dispatches a 32-round program,
    and pays for no post-convergence rounds beyond the masked no-op tail of
    its final chunk.

    The global round index — and with it the secure keystream space — is
    threaded across chunks automatically: chunk i+1's round_offset is
    `round_offset` + total rounds *executed* so far, which is exactly the
    keystream-disjointness contract (halted rounds consume none).

    `spec.n_rounds` is ignored (chunk sizes are chosen here). A spec
    without `halt_fn` is allowed: the job simply runs all `max_rounds`
    rounds (useful to share this entry point across workloads).

    `donate_state` (default True) donates each dispatch's carried-state
    buffers: the chunk loop always feeds a chunk's output state into the
    next chunk, so XLA can write the new state into the old one's storage
    instead of re-allocating it every dispatch. The caller's `init_state`
    is protected by ONE defensive device copy up front (donation would
    otherwise delete the caller's buffers on the first chunk); every
    subsequent dispatch re-uses storage with zero copies.

    `runners`: optional mutable dict mapping chunk size -> runner, reused
    across calls to amortize XLA compiles. Callers own its validity: it must
    have been populated with the SAME spec (sans n_rounds) / mesh / secure /
    impl / donation arguments.
    """
    if max_rounds < 1:
        raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
    if min_chunk < 1 or growth < 1:
        raise ValueError(f"min_chunk and growth must be >= 1, got {min_chunk}, {growth}")
    max_chunk = min(max_chunk or max_rounds, max_rounds)
    runners = {} if runners is None else runners

    state = init_state
    if donate_state:
        # one up-front copy shields the caller's init_state buffers from the
        # first chunk's donation; all later chunks donate run_until's own
        # output state, which nothing else holds
        state = jax.tree.map(lambda x: jnp.array(x, copy=True), init_state)
    executed = dispatched = n_dispatches = 0
    halted = False
    aux_chunks: list = []
    dropped_chunks: list = []
    chunk = min(max(1, min_chunk), max_chunk)
    while executed < max_rounds and not halted:
        n = min(chunk, max_rounds - executed)
        runner = runners.get(n)
        if runner is None:
            runner = runners[n] = make_iterative_runner(
                replace(spec, n_rounds=n), mesh, axis_name, secure,
                chacha_impl=chacha_impl, loop_impl=loop_impl,
                coalesce=coalesce, donate_state=donate_state)
        out = runner(inputs, state, round_offset + executed)
        if spec.halt_fn is None:
            state, aux, dropped = out
            n_exec, chunk_halted = n, False
        else:
            state, aux, dropped, n_exec, chunk_halted = out
            n_exec, chunk_halted = int(n_exec), bool(chunk_halted)
        n_dispatches += 1
        dispatched += n
        aux_chunks.append(jax.tree.map(lambda a: np.asarray(a)[:n_exec], aux))
        dropped_chunks.append(np.asarray(dropped)[:n_exec])
        if warn_on_overflow:
            _warn_overflow(dropped_chunks[-1], round_offset + executed,
                           runner.trace_info, stacklevel=4)
        executed += n_exec
        halted = chunk_halted
        chunk = min(chunk * growth, max_chunk)

    aux = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *aux_chunks)
    dropped = np.concatenate(dropped_chunks) if dropped_chunks else np.zeros((0,), np.int32)
    return RunUntilResult(
        state=state,
        aux=aux,
        dropped=dropped,
        rounds_executed=executed,
        rounds_dispatched=dispatched,
        n_dispatches=n_dispatches,
        halted=halted,
    )

"""AdamW with decoupled weight decay and global-norm gradient clipping.

Optimizer state shards exactly like the parameters (same logical axes), so
ZeRO-style sharding falls out of the params' NamedShardings.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    return {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, lr, cfg: AdamWConfig = AdamWConfig()):
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["nu"], grads)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, {"grad_norm": gn}

"""Synthetic token streams with learnable structure (for the examples/tests).

A k-order Markov-ish stream: token t depends on (t-1) via a fixed random
permutation mixed with noise, so a model can reduce loss well below uniform —
enough to validate end-to-end training dynamics without external data.
"""

from __future__ import annotations

import numpy as np


def synthetic_tokens(n_tokens: int, vocab: int, seed: int = 0, noise: float = 0.3):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab)
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(vocab)
    noise_draw = rng.random(n_tokens)
    noise_tok = rng.integers(0, vocab, n_tokens)
    for i in range(1, n_tokens):
        toks[i] = noise_tok[i] if noise_draw[i] < noise else perm[toks[i - 1]]
    return toks


def batches(tokens: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Yield (batch, seq) int32 batches forever (with wraparound)."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, batch)
        yield np.stack([tokens[i : i + seq] for i in idx]).astype(np.int32)

"""Secure sharded input pipeline — the paper's data path feeding train_step.

Shards are encrypted at rest (host side, k_data) exactly like the paper's
MAP_DATATYPE splits; `next_batch()` hands the *ciphertext* plus its keystream
counter to the jitted step, which decrypts in-graph (see
repro.train.step.SecureIngest). The host never needs to hold plaintext after
sharding — and a checkpoint restart resumes the counter stream exactly.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.crypto.ctr import encrypt_array, words_for
from repro.crypto.keys import SessionKeys


@dataclass
class SecureShardedSource:
    """Encrypts fixed-shape batches drawn from a token array."""

    tokens: np.ndarray
    batch: int
    seq: int
    session: SessionKeys
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._kw = self.session.words("data")
        self._nw = SessionKeys.nonce_words("data", 0)
        self._ctr = 0
        self._blocks_per_batch = -(-words_for((self.batch, self.seq), np.int32) // 16)

    @property
    def state(self) -> dict:
        return {"ctr": self._ctr, "rng": self._rng.bit_generator.state}

    def restore(self, state: dict):
        self._ctr = state["ctr"]
        self._rng.bit_generator.state = state["rng"]

    def next_batch(self):
        """Returns {"tokens": ciphertext (B,S) int32, "ctr": uint32}."""
        n = len(self.tokens) - self.seq - 1
        idx = self._rng.integers(0, n, self.batch)
        plain = np.stack([self.tokens[i : i + self.seq] for i in idx]).astype(np.int32)
        ctr = self._ctr
        self._ctr += self._blocks_per_batch
        ct = encrypt_array(jnp.asarray(plain), self._kw, self._nw, ctr)
        return {"tokens": ct, "ctr": jnp.uint32(ctr)}

from repro.data.pipeline import SecureShardedSource
from repro.data.synthetic import synthetic_tokens

__all__ = ["SecureShardedSource", "synthetic_tokens"]

"""Canonical jobs: the paper's word count (Listings 1-2) and k-means (§V).

The sources below are the direct analogues of the paper's Lua scripts — the
same special functions (`map`, `combine`, `hash`, `reduce`), the same
framework-provided `push(key, value)`, shipped encrypted and exec'd only
inside the worker.
"""

from __future__ import annotations

import numpy as np

from repro.crypto.keys import KeyHierarchy
from repro.runtime.node import Client, MapReduceJob, SecurityPolicy, Worker
from repro.runtime.sim import Cluster, TimingModel

# --- word count (paper Listings 1 & 2, ~20 LOC of user code) -----------------

WORDCOUNT_MAP = """
def map(key, value):
    for word in value.split():
        push(word, 1)

def combine(key, values):
    push(key, sum(values))

def hash(key, rcount):
    return ord(str(key)[0]) % rcount
"""

WORDCOUNT_REDUCE = """
def reduce(key, values):
    push(key, sum(values))
"""

# --- k-means (paper §III fig 1, §V) -------------------------------------------

KMEANS_MAP = """
def map(key, value):
    # value: [x, y]; consts["centers"]: [[cx, cy], ...]
    best, best_d = 0, None
    for i, c in enumerate(consts["centers"]):
        d = 0.0
        for a, b in zip(value, c):
            d += (a - b) * (a - b)
        if best_d is None or d < best_d:
            best, best_d = i, d
    push(best, value + [1.0])

def combine(key, values):
    acc = [0.0] * len(values[0])
    for v in values:
        for i, x in enumerate(v):
            acc[i] += x
    push(key, acc)

def hash(key, rcount):
    return int(key) % rcount
"""

KMEANS_REDUCE = """
def reduce(key, values):
    acc = [0.0] * len(values[0])
    for v in values:
        for i, x in enumerate(v):
            acc[i] += x
    n = max(acc[-1], 1e-9)
    push(key, [a / n for a in acc[:-1]])
"""


def make_cluster(
    n_workers: int,
    *,
    master: bytes = b"\x42" * 32,
    policy: SecurityPolicy | None = None,
    timing: TimingModel | None = None,
    speeds: dict[str, float] | None = None,
    rogue: set[str] | None = None,
):
    """Stand up client + router + workers; returns (cluster, client, workers)."""
    policy = policy or SecurityPolicy()
    kh = KeyHierarchy(master=master)
    kh.attestation.enroll(b"worker-code-v1")
    cluster = Cluster(header_key=kh.session.header, timing=timing)
    client = cluster.add(Client("client", kh, policy=policy))
    workers = []
    for i in range(n_workers):
        name = f"w{i}"
        identity = b"evil-code" if rogue and name in rogue else b"worker-code-v1"
        w = cluster.add(
            Worker(
                name,
                kh.session,
                speed=(speeds or {}).get(name, 1.0),
                code_identity=identity,
                policy=policy,
            )
        )
        w.start()
        workers.append(w)
    return cluster, client, workers


def run_wordcount(cluster: Cluster, client: Client, lines: list[str],
                  n_mappers: int, n_reducers: int, job_id: str = "wc"):
    job = MapReduceJob(
        job_id=job_id,
        map_source=WORDCOUNT_MAP,
        reduce_source=WORDCOUNT_REDUCE,
        data=lines,
        n_mappers=n_mappers,
        n_reducers=n_reducers,
    )
    client.submit(job)
    cluster.run_until(lambda: job_id in client.completed)
    return dict(client.completed[job_id]["pairs"]), client.completed[job_id]


def run_kmeans(cluster: Cluster, client: Client, points: np.ndarray, k: int,
               *, n_mappers: int, n_reducers: int, max_iter: int = 50,
               threshold: float | None = None, job_prefix: str = "km"):
    """Iterated MapReduce k-means with the paper's diag/1000 stop rule."""
    pts = [list(map(float, p)) for p in np.asarray(points)]
    centers = [list(map(float, p)) for p in np.asarray(points)[:k]]
    if threshold is None:
        lo, hi = np.min(points, axis=0), np.max(points, axis=0)
        threshold = float(np.linalg.norm(hi - lo)) / 1000.0

    history = []
    for it in range(max_iter):
        jid = f"{job_prefix}{it}"
        job = MapReduceJob(
            job_id=jid,
            map_source=KMEANS_MAP,
            reduce_source=KMEANS_REDUCE,
            data=pts,
            n_mappers=n_mappers,
            n_reducers=n_reducers,
            consts={"centers": centers},
        )
        client.submit(job)
        cluster.run_until(lambda: jid in client.completed)
        new = dict(client.completed[jid]["pairs"])
        new_centers = [new.get(i, centers[i]) for i in range(k)]
        shift = float(
            np.mean(np.linalg.norm(np.array(new_centers) - np.array(centers), axis=1))
        )
        history.append(
            {"iter": it, "shift": shift, "elapsed": client.completed[jid]["elapsed"]}
        )
        centers = new_centers
        if shift < threshold:
            break
    return np.array(centers, np.float32), history

"""Client and worker nodes (the paper's Fig. 2 entities), with fault tolerance.

User code ships exactly like the paper's Lua scripts: a *source string*
defining `map(key, value)` / optional `combine(key, values)` / `hash(key,
rcount)` for mappers and `reduce(key, values)` for reducers, executed in a
restricted namespace where the framework injects `push(key, value)`. The
source travels ChaCha20-encrypted (k_code) and is only exec'd inside the
worker ("enclave"); the SCBR router never holds the payload keys.

Security policy toggles reproduce the paper's 4-combo evaluation:
  encryption — payload cipher on the wire (headers always sealed: SCBR needs
               them in its own enclave);
  enclave    — per-message enclave-transition cost + SecurePager working-set
               costs (EPC paging analogue).
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.crypto.keys import Attestation, KeyHierarchy, SessionKeys
from repro.core.paging import SecurePager
from repro.pubsub import protocol as pr
from repro.pubsub.messages import Message, Subscription
from repro.runtime.sim import Cluster, Entity

MAP_ACK = "MAP_ACK"
RESHUFFLE = "RESHUFFLE"

_SAFE_BUILTINS = {
    "abs": abs, "min": min, "max": max, "sum": sum, "len": len, "range": range,
    "enumerate": enumerate, "zip": zip, "float": float, "int": int, "str": str,
    "sorted": sorted, "round": round, "list": list, "dict": dict, "tuple": tuple,
    "ord": ord, "chr": chr, "set": set, "map": map, "filter": filter, "bool": bool,
}


def load_script(source: str, consts: dict) -> dict:
    """exec the shipped script in a restricted namespace (the "Lua VM")."""
    ns: dict[str, Any] = {"__builtins__": dict(_SAFE_BUILTINS), "math": math, "consts": consts}
    exec(source, ns)  # runs only inside the worker "enclave"
    return ns


def default_hash(key, rcount: int) -> int:
    """Paper Listing 1: `string.byte(key, 1) % rcount`."""
    return ord(str(key)[0]) % rcount


@dataclass
class SecurityPolicy:
    encryption: bool = True
    enclave: bool = True


@dataclass
class MapReduceJob:
    job_id: str
    map_source: str          # defines map(key,value) [+ combine, hash]
    reduce_source: str       # defines reduce(key, values)
    data: list               # rows; split "line by line" round-robin
    n_mappers: int
    n_reducers: int
    consts: dict = field(default_factory=dict)


class _Script:
    """Instantiated user code with the framework's push() collector."""

    def __init__(self, source: str, consts: dict):
        self.ns = load_script(source, consts)

    def _call(self, name: str, *args):
        pairs: list = []
        self.ns["push"] = lambda k, v: pairs.append((k, v))
        self.ns[name](*args)
        return pairs

    def map(self, key, value):
        return self._call("map", key, value)

    def combine(self, key, values):
        if "combine" not in self.ns:
            return [(key, v) for v in values]
        return self._call("combine", key, values)

    def reduce(self, key, values):
        return self._call("reduce", key, values)

    def hash(self, key, rcount: int) -> int:
        if "hash" in self.ns:
            return int(self.ns["hash"](key, rcount)) % rcount
        return default_hash(key, rcount)


class _SecureEndpoint(Entity):
    """Shared seal/open helpers with timing charges."""

    session: SessionKeys
    policy: SecurityPolicy

    def _seal(self, header: dict, payload_obj, key_label: str) -> Message:
        raw = json.dumps(payload_obj).encode()
        key = getattr(self.session, key_label)
        if self.policy.encryption:
            msg = Message.seal(header, raw, self.session.header, key, sender=self.name)
        else:
            msg = Message.seal(header, b"", self.session.header, key, sender=self.name)
            msg.payload_ct = raw  # plaintext on the wire
        return msg

    def _open(self, msg: Message, key_label: str):
        if self.policy.encryption:
            raw = msg.open_payload(getattr(self.session, key_label))
        else:
            raw = msg.payload_ct
        return json.loads(raw) if raw else None

    def _crypto_cost(self, nbytes: int) -> float:
        return self.cluster.timing.crypto_delay(nbytes) if self.policy.encryption else 0.0

    def _enclave_cost(self) -> float:
        return self.cluster.timing.enclave_call_s if self.policy.enclave else 0.0


class Worker(_SecureEndpoint):
    """A node that can assume the mapper or reducer role (paper §IV)."""

    def __init__(self, name: str, session: SessionKeys, *, speed: float = 1.0,
                 code_identity: bytes = b"worker-code-v1", role_pref: str = "any",
                 policy: SecurityPolicy | None = None):
        self.name = name
        self.session = session
        self.speed = speed
        self.code_identity = code_identity
        self.role_pref = role_pref
        self.policy = policy or SecurityPolicy()
        self.alive = True
        self.busy_until = 0.0
        self._jobs: dict[str, dict] = {}
        self.pager: SecurePager | None = None

    # -- lifecycle ---------------------------------------------------------------

    def start(self, hb_interval: float = 0.05):
        self.hb_interval = hb_interval
        self.cluster.router.subscribe(
            pr.sub_job_openings(self.name).seal(self.session.header)
        )
        self.cluster.schedule(0.0, self._heartbeat)

    def _heartbeat(self):
        if not self.alive:
            return
        self.cluster.publish(
            self._seal({"type": pr.HEARTBEAT, "worker": self.name}, None, "header"),
            stream="ctl",  # dedicated connection: never blocked behind data
        )
        self.cluster.schedule(self.hb_interval, self._heartbeat)

    # -- message handling ----------------------------------------------------------

    def on_message(self, msg: Message):
        header = msg.open_header(self.session.header)
        t = header["type"]
        if t == pr.JOB_OPENING:
            self._apply(header)
        elif t in (pr.MAP_CODETYPE, pr.REDUCE_CODETYPE):
            self._receive_code(header, msg)
        elif t == pr.MAP_DATATYPE:
            self._map_split(header, msg)
        elif t == pr.REDUCE_DATATYPE:
            self._receive_pairs(header, msg)
        elif t == pr.MAP_EOS:
            self._receive_eos(header)
        elif t == RESHUFFLE:
            self._reshuffle(header)

    def _apply(self, header: dict):
        """Paper Fig. 3: JOB_DETAILS with our code/data subscriptions."""
        job_id = header["job"]
        subs = {}
        for role in ("mapper", "reducer"):
            subs[role] = [
                pr.sub_code(self.name, job_id, role).seal(self.session.header).hex(),
                pr.sub_data(self.name, job_id, role).seal(self.session.header).hex(),
            ]
        subs["common"] = [
            pr.sub_eos(self.name, job_id).seal(self.session.header).hex(),
            Subscription(
                constraints=(("type", "==", RESHUFFLE), ("job", "==", job_id)),
                subscriber=self.name,
            ).seal(self.session.header).hex(),
        ]
        payload = {
            "worker": self.name,
            "role_pref": self.role_pref,
            "measurement": Attestation.measure(self.code_identity),
            "subs": subs,
        }
        self.cluster.publish(
            self._seal({"type": pr.JOB_DETAILS, "job": job_id}, payload, "header")
        )

    def _receive_code(self, header: dict, msg: Message):
        code = self._open(msg, "code")
        role = "mapper" if header["type"] == pr.MAP_CODETYPE else "reducer"
        if self.policy.enclave and self.pager is None:
            self.pager = SecurePager(self.cluster.timing.epc_budget_bytes, self.session.page)
        self._jobs[header["job"]] = {
            "role": role,
            "slot": code["slot"],
            "script": _Script(code["source"], code.get("consts", {})),
            "mappers": code.get("mappers", []),
            "reducers": code.get("reducers", []),
            "n_mappers": code.get("n_mappers", 0),
            "n_reducers": code.get("n_reducers", 0),
            "seen_splits": set(),
            "eos_slots": set(),
            "groups": {},        # reducer: key -> [values]
            "stored": [],        # reducer: pager page ids
            "out_buffers": {},   # mapper: reducer slot -> [(split_id, pairs)]
            "done_splits": set(),
            "sent_eos": False,
        }

    # -- mapper ------------------------------------------------------------------

    def _charge(self, seconds: float) -> float:
        """Occupy this worker; returns delay until completion (from now)."""
        start = max(self.cluster.now, self.busy_until)
        self.busy_until = start + seconds
        return self.busy_until - self.cluster.now

    def _pager_charge(self, fn) -> float:
        if not (self.policy.enclave and self.pager):
            fn()
            return 0.0
        before = self.pager.stats.modeled_seconds
        fn()
        return self.pager.stats.modeled_seconds - before

    def _map_split(self, header: dict, msg: Message):
        st = self._jobs.get(header["job"])
        if st is None or st["role"] != "mapper":
            return
        if header.get("eos"):
            delay = self._charge(self._enclave_cost())
            st["sent_eos"] = True
            self.cluster.publish(
                self._seal(
                    {"type": pr.MAP_EOS, "job": header["job"], "slot": st["slot"]},
                    None, "header",
                ),
                extra_delay=delay,
            )
            return
        split_id = header["split"]
        if split_id in st["done_splits"]:
            return  # duplicate split (client retry) — idempotent
        rows = self._open(msg, "data")
        tm = self.cluster.timing

        work = 0.0
        work += self._enclave_cost() + self._crypto_cost(msg.wire_bytes)
        # working set through the pager (EPC model)
        page_cost = self._pager_charge(
            lambda: self.pager.store(f"{header['job']}/split/{split_id}", msg.payload_ct)
            if self.pager
            else None
        )
        work += page_cost

        script = st["script"]
        pairs: list = []
        for i, row in enumerate(rows):
            pairs.extend(script.map(f"{split_id}:{i}", row))
        # local combine (paper's optional combiner)
        grouped: dict = {}
        for k, v in pairs:
            grouped.setdefault(k, []).append(v)
        combined: list = []
        for k, vs in grouped.items():
            combined.extend(script.combine(k, vs))
        work += tm.item_cost_s * (len(rows) + len(pairs) + len(combined)) / self.speed

        r = st["n_reducers"]
        by_slot: dict[int, list] = {}
        for k, v in combined:
            by_slot.setdefault(script.hash(k, r), []).append((k, v))

        delay = self._charge(work)
        for slot, kvs in by_slot.items():
            st["out_buffers"].setdefault(slot, []).append((split_id, kvs))
            dest = st["reducers"][slot]
            out = self._seal(
                {
                    "type": pr.REDUCE_DATATYPE,
                    "job": header["job"],
                    "dest": dest,
                    "split": split_id,
                    "mslot": st["slot"],
                },
                kvs,
                "shuffle",
            )
            self.cluster.publish(out, extra_delay=delay + self._crypto_cost(out.wire_bytes))
        st["done_splits"].add(split_id)
        self.cluster.publish(
            self._seal(
                {"type": MAP_ACK, "job": header["job"], "split": split_id, "worker": self.name},
                None, "header",
            ),
            extra_delay=delay,
        )

    def _reshuffle(self, header: dict):
        """A reducer slot moved: re-send buffered outputs + EOS for that slot."""
        st = self._jobs.get(header["job"])
        if st is None or st["role"] != "mapper":
            return
        slot = header["slot"]
        st["reducers"][slot] = header["new_worker"]
        delay = self._charge(self._enclave_cost())
        for split_id, kvs in st["out_buffers"].get(slot, []):
            out = self._seal(
                {
                    "type": pr.REDUCE_DATATYPE,
                    "job": header["job"],
                    "dest": header["new_worker"],
                    "split": split_id,
                    "mslot": st["slot"],
                },
                kvs,
                "shuffle",
            )
            self.cluster.publish(out, extra_delay=delay + self._crypto_cost(out.wire_bytes))
        if st["sent_eos"]:
            # FIFO on the mapper->new-reducer channel keeps this EOS behind
            # the re-sent data above.
            self.cluster.publish(
                self._seal(
                    {"type": pr.MAP_EOS, "job": header["job"], "slot": st["slot"]},
                    None, "header",
                ),
                extra_delay=delay,
            )

    # -- reducer -------------------------------------------------------------------

    def _receive_pairs(self, header: dict, msg: Message):
        st = self._jobs.get(header["job"])
        if st is None or st["role"] != "reducer":
            return
        # dedupe by split alone: a backup/replacement mapper produces the
        # identical output for the same split under a different slot.
        if header["split"] in st["seen_splits"]:
            return
        st["seen_splits"].add(header["split"])
        work = self._enclave_cost() + self._crypto_cost(msg.wire_bytes)
        pid = f"{header['job']}/rd/{header['split']}/{header['mslot']}"
        work += self._pager_charge(
            lambda: self.pager.store(pid, msg.payload_ct) if self.pager else None
        )
        st["stored"].append(pid)
        kvs = self._open(msg, "shuffle")
        for k, v in kvs:
            st["groups"].setdefault(json.dumps(k), []).append(v)
        work += self.cluster.timing.item_cost_s * len(kvs) / self.speed
        self._charge(work)

    def _receive_eos(self, header: dict):
        st = self._jobs.get(header["job"])
        if st is None or st["role"] != "reducer":
            return
        st["eos_slots"].add(header["slot"])
        if len(st["eos_slots"]) < st["n_mappers"]:
            return
        # all mappers done -> run reduce (paper: "more memory intensive")
        work = self._enclave_cost()
        if self.pager:
            for pid in st["stored"]:
                work += self._pager_charge(lambda p=pid: self.pager.load(p))
        script = st["script"]
        out_pairs = []
        n_vals = 0
        for k_json, vs in sorted(st["groups"].items()):
            out_pairs.extend(script.reduce(json.loads(k_json), vs))
            n_vals += len(vs)
        work += self.cluster.timing.item_cost_s * n_vals / self.speed
        delay = self._charge(work)
        out = self._seal(
            {"type": pr.RESULT, "job": header["job"], "slot": st["slot"]},
            out_pairs,
            "data",
        )
        self.cluster.publish(out, extra_delay=delay + self._crypto_cost(out.wire_bytes))


class Client(_SecureEndpoint):
    """Data owner: hires via pub/sub, provisions code+data, tracks completion.

    Fault tolerance (beyond the paper, which defers it): heartbeat failure
    detection; mapper replacement re-runs unacked splits through the normal
    hiring flow; reducer replacement triggers RESHUFFLE of buffered mapper
    outputs; stragglers get speculative backup splits; reducers dedupe by
    (split, mapper-slot).
    """

    def __init__(self, name: str, keys: KeyHierarchy, *, policy: SecurityPolicy | None = None,
                 hb_interval: float = 0.05, straggler_factor: float = 6.0):
        self.name = name
        self.keys = keys
        self.session = keys.session
        self.policy = policy or SecurityPolicy()
        self.alive = True
        self.hb_interval = hb_interval
        self.straggler_factor = straggler_factor
        self._jobs: dict[str, dict] = {}
        self._last_hb: dict[str, float] = {}
        self.completed: dict[str, dict] = {}

    # -- submission ------------------------------------------------------------

    def submit(self, job: MapReduceJob):
        jid = job.job_id
        hdr = self.session.header
        for sub in (
            pr.sub_job_details(self.name, jid),
            pr.sub_results(self.name, jid),
            pr.sub_heartbeats(self.name),
            Subscription(constraints=(("type", "==", MAP_ACK), ("job", "==", jid)),
                         subscriber=self.name),
        ):
            self.cluster.router.subscribe(sub.seal(hdr))
        self._jobs[jid] = {
            "job": job,
            "mappers": [None] * job.n_mappers,
            "reducers": [None] * job.n_reducers,
            "standby": [],
            "hired": set(),
            "splits": {},           # split_id -> {"rows", "mapper_slot", "acked", "sent_at"}
            "provisioned": False,
            "results": {},
            "t_submit": self.cluster.now,
            "ack_times": [],
        }
        self.cluster.publish(
            self._seal({"type": pr.JOB_OPENING, "job": jid}, {"job": jid}, "header")
        )
        self.cluster.schedule(self.hb_interval * 3, self._liveness_check, jid)

    # -- message handling ----------------------------------------------------------

    def on_message(self, msg: Message):
        header = msg.open_header(self.session.header)
        t = header["type"]
        if t == pr.JOB_DETAILS:
            self._consider_hire(header, msg)
        elif t == MAP_ACK:
            self._on_ack(header)
        elif t == pr.RESULT:
            self._on_result(header, msg)
        elif t == pr.HEARTBEAT:
            self._last_hb[header["worker"]] = self.cluster.now

    def _consider_hire(self, header: dict, msg: Message):
        st = self._jobs.get(header["job"])
        if st is None:
            return
        d = self._open(msg, "header")
        w = d["worker"]
        if w in st["hired"]:
            return
        # simulated SGX attestation gate (paper's key-provisioning step)
        if not self.keys.attestation.verify(d["measurement"]):
            return
        slot_kind = None
        if not st["provisioned"]:
            if None in st["mappers"] and d["role_pref"] in ("any", "mapper"):
                slot_kind = "mapper"
            elif None in st["reducers"] and d["role_pref"] in ("any", "reducer"):
                slot_kind = "reducer"
        if slot_kind is None:
            if all(s["worker"] != w for s in st["standby"]):
                st["standby"].append(d)
            return
        self._hire(header["job"], d, slot_kind)
        if None not in st["mappers"] and None not in st["reducers"] and not st["provisioned"]:
            self._provision(header["job"])

    def _hire(self, jid: str, details: dict, role: str, slot: int | None = None):
        st = self._jobs[jid]
        w = details["worker"]
        roster = st["mappers"] if role == "mapper" else st["reducers"]
        if slot is None:
            slot = roster.index(None)
        roster[slot] = w
        st["hired"].add(w)
        # register the worker's subscriptions on its behalf (paper Fig. 3)
        for blob_hex in details["subs"][role] + details["subs"]["common"]:
            self.cluster.router.subscribe(bytes.fromhex(blob_hex))
        self._last_hb[w] = self.cluster.now
        return slot

    def _provision(self, jid: str):
        st = self._jobs[jid]
        job: MapReduceJob = st["job"]
        st["provisioned"] = True
        for slot, w in enumerate(st["mappers"]):
            self._send_code(jid, w, "mapper", slot)
        for slot, w in enumerate(st["reducers"]):
            self._send_code(jid, w, "reducer", slot)
        # paper: "data is split by the client among the mappers, line by line"
        st["slot_unacked"] = {s: 0 for s in range(job.n_mappers)}
        for i, row in enumerate(job.data):
            slot = i % job.n_mappers
            st["splits"][i] = {"rows": [row], "mapper_slot": slot, "acked": False,
                               "sent_at": self.cluster.now, "backup": False}
            st["slot_unacked"][slot] += 1
            self._send_split(jid, i)
        for slot, w in enumerate(st["mappers"]):
            self.cluster.publish(
                self._seal({"type": pr.MAP_DATATYPE, "job": jid, "dest": w, "eos": 1},
                           None, "data")
            )

    def _send_code(self, jid: str, worker: str, role: str, slot: int):
        st = self._jobs[jid]
        job: MapReduceJob = st["job"]
        code = {
            "slot": slot,
            "source": job.map_source if role == "mapper" else job.reduce_source,
            "consts": job.consts,
            "n_mappers": job.n_mappers,
            "n_reducers": job.n_reducers,
            "mappers": list(st["mappers"]),
            "reducers": list(st["reducers"]),
        }
        t = pr.MAP_CODETYPE if role == "mapper" else pr.REDUCE_CODETYPE
        self.cluster.publish(self._seal({"type": t, "job": jid, "dest": worker}, code, "code"))

    def _send_split(self, jid: str, split_id: int, to_slot: int | None = None):
        st = self._jobs[jid]
        sp = st["splits"][split_id]
        slot = to_slot if to_slot is not None else sp["mapper_slot"]
        dest = st["mappers"][slot]
        sp["sent_at"] = self.cluster.now
        self.cluster.publish(
            self._seal(
                {"type": pr.MAP_DATATYPE, "job": jid, "dest": dest, "split": split_id},
                sp["rows"],
                "data",
            )
        )

    def _on_ack(self, header: dict):
        jid = header["job"]
        st = self._jobs.get(jid)
        if st is None:
            return
        sp = st["splits"].get(header["split"])
        if sp and not sp["acked"]:
            sp["acked"] = True
            st["ack_times"].append(self.cluster.now - sp["sent_at"])
            # slot-coverage EOS: once every split of a mapper slot is acked
            # (possibly by backups), the client itself certifies end-of-stream
            # for that slot so reducers don't wait out a straggler.
            # (O(1) per-slot counter — a full scan here is O(splits^2))
            slot = sp["mapper_slot"]
            st["slot_unacked"][slot] -= 1
            if st["slot_unacked"][slot] == 0:
                self.cluster.publish(
                    self._seal({"type": pr.MAP_EOS, "job": jid, "slot": slot},
                               None, "header")
                )

    def _on_result(self, header: dict, msg: Message):
        st = self._jobs.get(header["job"])
        if st is None:
            return
        st["results"][header["slot"]] = self._open(msg, "data")
        if len(st["results"]) == st["job"].n_reducers:
            pairs = []
            for slot in sorted(st["results"]):
                pairs.extend([tuple(p) for p in st["results"][slot]])
            self.completed[header["job"]] = {
                "pairs": pairs,
                "t_complete": self.cluster.now,
                "elapsed": self.cluster.now - st["t_submit"],
            }

    # -- fault tolerance ------------------------------------------------------------

    def _liveness_check(self, jid: str):
        st = self._jobs.get(jid)
        if st is None or jid in self.completed:
            return
        timeout = 3 * self.hb_interval
        for role, roster in (("mapper", st["mappers"]), ("reducer", st["reducers"])):
            for slot, w in enumerate(roster):
                if w is None:
                    continue
                if self.cluster.now - self._last_hb.get(w, 0.0) > timeout:
                    self._replace(jid, role, slot, w)
        self._check_stragglers(jid)
        self.cluster.schedule(self.hb_interval * 2, self._liveness_check, jid)

    def _replace(self, jid: str, role: str, slot: int, dead: str):
        st = self._jobs[jid]
        roster = st["mappers"] if role == "mapper" else st["reducers"]
        roster[slot] = None
        st["hired"].discard(dead)
        if st["standby"]:
            details = st["standby"].pop(0)
            self._hire(jid, details, role, slot)
            self._recover(jid, role, slot)
        else:
            # no standby: re-open hiring (paper's Fig. 3 flow, again)
            st.setdefault("pending_recovery", []).append((role, slot))
            self.cluster.publish(
                self._seal({"type": pr.JOB_OPENING, "job": jid}, {"job": jid}, "header")
            )
            self.cluster.schedule(self.hb_interval, self._try_pending, jid)

    def _try_pending(self, jid: str):
        st = self._jobs.get(jid)
        if st is None or not st.get("pending_recovery"):
            return
        while st["pending_recovery"] and st["standby"]:
            role, slot = st["pending_recovery"].pop(0)
            details = st["standby"].pop(0)
            self._hire(jid, details, role, slot)
            self._recover(jid, role, slot)
        if st["pending_recovery"]:
            self.cluster.schedule(self.hb_interval, self._try_pending, jid)

    def _recover(self, jid: str, role: str, slot: int):
        st = self._jobs[jid]
        w = (st["mappers"] if role == "mapper" else st["reducers"])[slot]
        self._send_code(jid, w, role, slot)
        if role == "mapper":
            for sid, sp in st["splits"].items():
                if sp["mapper_slot"] == slot and not sp["acked"]:
                    self._send_split(jid, sid)
            self.cluster.publish(
                self._seal({"type": pr.MAP_DATATYPE, "job": jid, "dest": w, "eos": 1},
                           None, "data")
            )
        else:
            # tell mappers to re-route buffered output for this reducer slot
            self.cluster.publish(
                self._seal({"type": RESHUFFLE, "job": jid, "slot": slot, "new_worker": w},
                           None, "header")
            )

    def _check_stragglers(self, jid: str):
        st = self._jobs[jid]
        if not st["provisioned"] or not st["ack_times"]:
            return
        acks = sorted(st["ack_times"])
        median = acks[len(acks) // 2]
        limit = max(self.straggler_factor * median, 4 * self.hb_interval)
        live_slots = [s for s, w in enumerate(st["mappers"]) if w is not None]
        for sid, sp in st["splits"].items():
            if sp["acked"] or sp["backup"]:
                continue
            if self.cluster.now - sp["sent_at"] > limit:
                # speculative backup task on another live mapper
                others = [s for s in live_slots if s != sp["mapper_slot"]]
                if others:
                    sp["backup"] = True
                    self._send_split(jid, sid, to_slot=others[sid % len(others)])

"""Deterministic virtual-time cluster simulation.

Entities exchange messages only through the SCBR router; the simulator
charges virtual time for network transfer, per-message enclave transitions,
cipher streaming, and enclave paging (via each worker's SecurePager). Wall
time is also tracked for the real crypto work (the ciphers actually run).

Determinism: a single event heap ordered by (time, seq); no wall-clock
dependence in control flow, so failure/straggler tests are reproducible.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable

from repro.pubsub.messages import Message
from repro.pubsub.router import ScbrRouter


@dataclass
class TimingModel:
    """Virtual-time cost constants (calibrated to paper-era hardware).

    The compile-vs-steady split models the serving cost structure measured
    by `benchmarks/bench_service.py`: tracing + XLA-compiling one fused
    round program (`xla_compile_s`, tens of seconds on the secure path)
    against the per-chunk host round trip (`dispatch_s`) and the per-round
    map/shuffle/reduce work — the asymmetry the size-bucketed runner cache
    exists to exploit (`repro.serve.service`).
    """

    net_latency_s: float = 100e-6
    net_bw_bytes_s: float = 1.0e9  # 10 GbE-ish
    enclave_call_s: float = 4.0e-6  # ECALL/OCALL round trip
    crypto_bw_bytes_s: float = 2.0e9  # AES-CTR/ChaCha20 software stream
    item_cost_s: float = 2.0e-7  # per (key,value) map/reduce work
    epc_budget_bytes: int = 32 * 1024 * 1024  # usable trusted memory per worker
    xla_compile_s: float = 30.0  # trace + compile ONE fused-round program
    dispatch_s: float = 200e-6  # host->device round trip per chunk dispatch

    def net_delay(self, nbytes: int) -> float:
        return self.net_latency_s + nbytes / self.net_bw_bytes_s

    def crypto_delay(self, nbytes: int) -> float:
        return nbytes / self.crypto_bw_bytes_s

    def round_delay(self, n_local_items: int, item_bytes: int = 8) -> float:
        """Steady-state cost of ONE executed round on one shard's slice."""
        nbytes = n_local_items * item_bytes
        return (self.enclave_call_s + n_local_items * self.item_cost_s
                + self.crypto_delay(nbytes) + self.net_delay(nbytes))


class Entity:
    name: str = "?"
    alive: bool = True

    def attach(self, cluster: "Cluster"):
        self.cluster = cluster

    def on_message(self, msg: Message):  # pragma: no cover - interface
        raise NotImplementedError


class Cluster:
    def __init__(self, header_key: bytes, timing: TimingModel | None = None):
        self.router = ScbrRouter(header_key)
        self.timing = timing or TimingModel()
        self.now = 0.0
        self._events: list = []
        self._seq = itertools.count()
        self.entities: dict[str, Entity] = {}
        self.delivered_messages = 0
        self._fifo: dict[tuple[str, str], float] = {}  # per-channel FIFO (ZeroMQ/TCP)

    # -- entity / event plumbing ------------------------------------------------

    def add(self, entity: Entity):
        self.entities[entity.name] = entity
        entity.attach(self)
        return entity

    def schedule(self, delay: float, fn: Callable, *args):
        heapq.heappush(self._events, (self.now + delay, next(self._seq), fn, args))

    def publish(self, msg: Message, extra_delay: float = 0.0, stream: str = "data"):
        """Entity -> router -> matching outboxes, with per-target delivery events.

        Deliveries on one (sender, target, stream) channel preserve publish
        order — the FIFO guarantee a ZeroMQ/TCP connection gives the paper's
        protocol (EOS must not overtake the data that precedes it). Control
        traffic (heartbeats) uses its own stream so a busy worker's data queue
        cannot head-of-line-block its liveness signal.
        """
        targets = self.router.publish(msg)
        for t in targets:
            at = self.now + self.timing.net_delay(msg.wire_bytes) + extra_delay
            chan = (msg.sender, t, stream)
            at = max(at, self._fifo.get(chan, 0.0) + 1e-9)
            self._fifo[chan] = at
            self.schedule(at - self.now, self._deliver, t, msg)
        return targets

    def _deliver(self, target: str, msg: Message):
        e = self.entities.get(target)
        if e is None or not e.alive:
            return  # dropped on the floor — failure detector handles it
        self.delivered_messages += 1
        e.on_message(msg)

    def run(self, until: float | None = None, max_events: int = 2_000_000):
        """Process events up to virtual time `until` (periodic control-plane
        events — heartbeats, liveness checks — keep the queue nonempty, so an
        unbounded run only makes sense via `run_until`)."""
        n = 0
        while self._events and n < max_events:
            t, _, fn, args = heapq.heappop(self._events)
            if until is not None and t > until:
                self.now = until
                heapq.heappush(self._events, (t, next(self._seq), fn, args))
                return
            self.now = max(self.now, t)
            fn(*args)
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted — livelock?")

    def run_until(self, predicate: Callable[[], bool], t_max: float = 300.0,
                  max_events: int = 5_000_000) -> bool:
        """Run until `predicate()` holds. Raises on virtual-time/event budget."""
        n = 0
        while self._events and n < max_events:
            if predicate():
                return True
            t, _, fn, args = heapq.heappop(self._events)
            if t > t_max:
                raise TimeoutError(f"virtual time budget {t_max}s exhausted at t={t:.3f}")
            self.now = max(self.now, t)
            fn(*args)
            n += 1
        if predicate():
            return True
        raise RuntimeError("event queue drained/budget exhausted before completion")

    # -- fault injection ---------------------------------------------------------

    def kill_at(self, name: str, t: float):
        self.schedule(max(0.0, t - self.now), self._kill, name)

    def _kill(self, name: str):
        e = self.entities.get(name)
        if e is not None:
            e.alive = False
            self.router.unsubscribe_all(name)


# -- admission-policy testbed ----------------------------------------------------
#
# Virtual-time replay of the serving scheduler (`repro.serve.service`) against
# the TimingModel's compile-vs-steady cost split, so admission policies can be
# compared deterministically without a device: same FIFO admission into
# `max_concurrent` slots, same round-robin one-chunk-per-job dispatch, same
# geometric chunk ladder — only the runner-cache policy varies.


@dataclass
class SimJob:
    """One job in an arrival trace (sizes in items, budget in rounds).

    `priority > 0` jobs admit ahead of the normal FIFO class, mirroring
    `SecureJobService.submit_*(priority=...)`; active jobs are never
    preempted."""

    arrival_s: float
    n_items: int
    n_rounds: int
    kind: str = "kmeans"
    priority: int = 0


def burst_trace(n_jobs: int = 16, *, base_items: int = 4096, jitter: float = 0.3,
                n_rounds: int = 8, seed: int = 0) -> list[SimJob]:
    """A burst: `n_jobs` near-simultaneous arrivals with sizes jittered
    around `base_items` — the regime where size buckets collapse many
    distinct sizes onto few compiled programs."""
    import numpy as np

    rng = np.random.default_rng(seed)
    sizes = base_items * rng.uniform(1.0 - jitter, 1.0 + jitter, size=n_jobs)
    return [SimJob(arrival_s=1e-3 * i, n_items=max(1, int(s)), n_rounds=n_rounds)
            for i, s in enumerate(sizes)]


def straggler_trace(n_jobs: int = 12, *, base_items: int = 4096,
                    period_s: float = 2.0, straggler_factor: int = 32,
                    straggler_rounds: int = 32, n_rounds: int = 8,
                    seed: int = 1) -> list[SimJob]:
    """Steady arrivals with ONE straggler (`straggler_factor`x bigger,
    `straggler_rounds` rounds) mid-trace — the head-of-line-blocking regime
    the round-robin chunk interleave is meant to survive."""
    import numpy as np

    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        size = max(1, int(base_items * rng.uniform(0.8, 1.2)))
        rounds = n_rounds
        if i == n_jobs // 2:
            size *= straggler_factor
            rounds = straggler_rounds
        jobs.append(SimJob(arrival_s=period_s * i, n_items=size, n_rounds=rounds))
    return jobs


class AdmissionSim:
    """Deterministic virtual-time testbed for service admission policies.

    `run(jobs, policy)` replays an arrival trace through the serving
    scheduler's exact control flow and returns makespan / latency / cache
    statistics. Policies:

      * 'bucketed'        — the shipped policy: inputs pad to geometric size
        buckets (`repro.serve.service.bucket_for`) and a (kind, bucket,
        chunk) program compiles ONCE process-wide;
      * 'compile-per-job' — the pre-service behavior: every job compiles
        every chunk size it dispatches, no sharing (the ad-hoc per-call
        runner dict).

    The simulated device serves one chunk at a time (the service's single
    dispatch thread); compiles also serialize on it, which is exactly the
    cold-start convoy the bucketed cache removes.
    """

    POLICIES = ("bucketed", "compile-per-job")

    def __init__(self, timing: TimingModel | None = None, *, n_shards: int = 8,
                 max_concurrent: int = 4, bucket_growth: float = 2.0,
                 max_resident: int | None = None,
                 min_chunk: int = 1, max_chunk: int = 8,
                 chunk_growth: int = 2):
        self.timing = timing or TimingModel()
        self.n_shards = n_shards
        self.max_concurrent = max_concurrent
        self.bucket_growth = bucket_growth
        self.max_resident = max_resident  # LRU program-cache cap (None = unbounded)
        self.min_chunk = max(1, min_chunk)
        self.max_chunk = max(self.min_chunk, max_chunk)
        self.chunk_growth = max(1, chunk_growth)  # geometric ladder factor

    def run(self, jobs: list[SimJob], policy: str = "bucketed") -> dict:
        if policy not in self.POLICIES:
            raise ValueError(f"policy must be one of {self.POLICIES}, got {policy!r}")
        from repro.serve.service import bucket_for

        from collections import OrderedDict

        order = sorted(range(len(jobs)), key=lambda i: (jobs[i].arrival_s, i))
        waiting = [(jobs[i], i) for i in order]
        active: list[dict] = []
        compiled: OrderedDict = OrderedDict()  # LRU, like RunnerCache
        t = 0.0
        hits = misses = evictions = 0
        latency = [0.0] * len(jobs)

        while waiting or active:
            if not active and waiting and waiting[0][0].arrival_s > t:
                t = waiting[0][0].arrival_s
            while waiting and len(active) < self.max_concurrent \
                    and waiting[0][0].arrival_s <= t:
                # two-level admission (mirrors SecureJobService): among the
                # ARRIVED prefix, high-priority jobs drain first, FIFO within
                # each class; active jobs are never preempted.
                n_arrived = 0
                while (n_arrived < len(waiting)
                       and waiting[n_arrived][0].arrival_s <= t):
                    n_arrived += 1
                k = next((k for k in range(n_arrived)
                          if waiting[k][0].priority > 0), 0)
                job, idx = waiting.pop(k)
                n_padded = (bucket_for(job.n_items, multiple=self.n_shards,
                                       growth=self.bucket_growth)
                            if policy == "bucketed" else job.n_items)
                active.append({"job": job, "idx": idx, "done": 0,
                               "chunk": self.min_chunk, "n_padded": n_padded})
            # round-robin: ONE chunk per active job per pass
            for st in list(active):
                job = st["job"]
                n = min(st["chunk"], job.n_rounds - st["done"])
                key = ((job.kind, st["n_padded"], n) if policy == "bucketed"
                       else (st["idx"], n))
                if key in compiled:
                    hits += 1
                    compiled.move_to_end(key)
                else:
                    compiled[key] = True
                    misses += 1
                    t += self.timing.xla_compile_s
                    if self.max_resident is not None:
                        while len(compiled) > self.max_resident:
                            compiled.popitem(last=False)
                            evictions += 1
                n_local = -(-st["n_padded"] // self.n_shards)
                t += self.timing.dispatch_s + n * self.timing.round_delay(n_local)
                st["done"] += n
                st["chunk"] = min(st["chunk"] * self.chunk_growth, self.max_chunk)
                if st["done"] >= job.n_rounds:
                    active.remove(st)
                    latency[st["idx"]] = t - job.arrival_s

        return {
            "policy": policy,
            "makespan_s": t,
            "mean_latency_s": sum(latency) / len(latency) if latency else 0.0,
            "max_latency_s": max(latency) if latency else 0.0,
            "per_job_latency_s": latency,
            "compiles": misses,
            "resident": len(compiled),
            "hits": hits,
            "misses": misses,
            "evictions": evictions,
        }

"""Deterministic virtual-time cluster simulation.

Entities exchange messages only through the SCBR router; the simulator
charges virtual time for network transfer, per-message enclave transitions,
cipher streaming, and enclave paging (via each worker's SecurePager). Wall
time is also tracked for the real crypto work (the ciphers actually run).

Determinism: a single event heap ordered by (time, seq); no wall-clock
dependence in control flow, so failure/straggler tests are reproducible.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable

from repro.pubsub.messages import Message
from repro.pubsub.router import ScbrRouter


@dataclass
class TimingModel:
    """Virtual-time cost constants (calibrated to paper-era hardware)."""

    net_latency_s: float = 100e-6
    net_bw_bytes_s: float = 1.0e9  # 10 GbE-ish
    enclave_call_s: float = 4.0e-6  # ECALL/OCALL round trip
    crypto_bw_bytes_s: float = 2.0e9  # AES-CTR/ChaCha20 software stream
    item_cost_s: float = 2.0e-7  # per (key,value) map/reduce work
    epc_budget_bytes: int = 32 * 1024 * 1024  # usable trusted memory per worker

    def net_delay(self, nbytes: int) -> float:
        return self.net_latency_s + nbytes / self.net_bw_bytes_s

    def crypto_delay(self, nbytes: int) -> float:
        return nbytes / self.crypto_bw_bytes_s


class Entity:
    name: str = "?"
    alive: bool = True

    def attach(self, cluster: "Cluster"):
        self.cluster = cluster

    def on_message(self, msg: Message):  # pragma: no cover - interface
        raise NotImplementedError


class Cluster:
    def __init__(self, header_key: bytes, timing: TimingModel | None = None):
        self.router = ScbrRouter(header_key)
        self.timing = timing or TimingModel()
        self.now = 0.0
        self._events: list = []
        self._seq = itertools.count()
        self.entities: dict[str, Entity] = {}
        self.delivered_messages = 0
        self._fifo: dict[tuple[str, str], float] = {}  # per-channel FIFO (ZeroMQ/TCP)

    # -- entity / event plumbing ------------------------------------------------

    def add(self, entity: Entity):
        self.entities[entity.name] = entity
        entity.attach(self)
        return entity

    def schedule(self, delay: float, fn: Callable, *args):
        heapq.heappush(self._events, (self.now + delay, next(self._seq), fn, args))

    def publish(self, msg: Message, extra_delay: float = 0.0, stream: str = "data"):
        """Entity -> router -> matching outboxes, with per-target delivery events.

        Deliveries on one (sender, target, stream) channel preserve publish
        order — the FIFO guarantee a ZeroMQ/TCP connection gives the paper's
        protocol (EOS must not overtake the data that precedes it). Control
        traffic (heartbeats) uses its own stream so a busy worker's data queue
        cannot head-of-line-block its liveness signal.
        """
        targets = self.router.publish(msg)
        for t in targets:
            at = self.now + self.timing.net_delay(msg.wire_bytes) + extra_delay
            chan = (msg.sender, t, stream)
            at = max(at, self._fifo.get(chan, 0.0) + 1e-9)
            self._fifo[chan] = at
            self.schedule(at - self.now, self._deliver, t, msg)
        return targets

    def _deliver(self, target: str, msg: Message):
        e = self.entities.get(target)
        if e is None or not e.alive:
            return  # dropped on the floor — failure detector handles it
        self.delivered_messages += 1
        e.on_message(msg)

    def run(self, until: float | None = None, max_events: int = 2_000_000):
        """Process events up to virtual time `until` (periodic control-plane
        events — heartbeats, liveness checks — keep the queue nonempty, so an
        unbounded run only makes sense via `run_until`)."""
        n = 0
        while self._events and n < max_events:
            t, _, fn, args = heapq.heappop(self._events)
            if until is not None and t > until:
                self.now = until
                heapq.heappush(self._events, (t, next(self._seq), fn, args))
                return
            self.now = max(self.now, t)
            fn(*args)
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted — livelock?")

    def run_until(self, predicate: Callable[[], bool], t_max: float = 300.0,
                  max_events: int = 5_000_000) -> bool:
        """Run until `predicate()` holds. Raises on virtual-time/event budget."""
        n = 0
        while self._events and n < max_events:
            if predicate():
                return True
            t, _, fn, args = heapq.heappop(self._events)
            if t > t_max:
                raise TimeoutError(f"virtual time budget {t_max}s exhausted at t={t:.3f}")
            self.now = max(self.now, t)
            fn(*args)
            n += 1
        if predicate():
            return True
        raise RuntimeError("event queue drained/budget exhausted before completion")

    # -- fault injection ---------------------------------------------------------

    def kill_at(self, name: str, t: float):
        self.schedule(max(0.0, t - self.now), self._kill, name)

    def _kill(self, name: str):
        e = self.entities.get(name)
        if e is not None:
            e.alive = False
            self.router.unsubscribe_all(name)

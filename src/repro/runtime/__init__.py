"""Simulated multi-node cluster runtime for the paper's protocol.

Deterministic virtual-time event simulation of: client (data owner), SCBR
router, worker nodes (mapper/reducer roles). Implements the session
establishment + provisioning protocol (paper Figs. 3-4), the paper's
line-by-line split distribution, mapper-side shuffle, EOS counting — plus the
fault-tolerance features a production deployment needs (the paper defers
these to future work): heartbeat failure detection, re-hiring through the
same JOB_OPENING flow, split re-execution, reducer reshuffle, speculative
backup tasks for stragglers, and result deduplication by split id.
"""

from repro.runtime.node import Client, MapReduceJob, Worker
from repro.runtime.sim import Cluster, TimingModel

__all__ = ["Client", "Worker", "MapReduceJob", "Cluster", "TimingModel"]

"""Logical-axis sharding rules -> PartitionSpecs.

Every parameter/activation is annotated with logical dimension names; a rule
table maps them to mesh axes. The production mesh is ('data','model') per pod
plus a 'pod' axis across pods; 'pod' composes with 'data' for batch/FSDP.

Default placement:
  batch   -> ('pod','data')      data parallel across pods
  fsdp    -> ('pod','data')      ZeRO-3 parameter/optimizer sharding; XLA
                                  all-gathers weights per layer inside scan
  vocab/heads/kv_heads/mlp/experts -> 'model'   tensor/expert parallelism
  seq_shard -> 'model'           sequence sharding inside MoE shuffle blocks
                                  and long-context KV caches
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat


@dataclass(frozen=True)
class ShardingRules:
    rules: dict

    def spec(self, axes: tuple) -> P:
        """axes: tuple of logical names (or None) per tensor dim."""
        out = []
        for a in axes:
            m = self.rules.get(a) if a is not None else None
            out.append(m)
        return P(*out)

    def with_overrides(self, **kw) -> "ShardingRules":
        r = dict(self.rules)
        r.update(kw)
        return ShardingRules(rules=r)


DEFAULT_RULES = ShardingRules(
    rules={
        "batch": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "seq": None,
        "seq_shard": "model",
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "layers": None,
        "state": None,
        "dconv": None,
    }
)

SINGLE_POD_RULES = DEFAULT_RULES.with_overrides(batch="data", fsdp="data")


def rules_for_mesh(mesh: Mesh, cfg=None) -> ShardingRules:
    """Rules restricted to the axes this mesh actually has (test meshes may
    lack 'model' or 'pod'; those logical axes fall back to replication).

    cfg.shard_strategy == "dp_sp": weights replicated (no TP), the 'model'
    axis is spent on sequence/context parallelism instead — the right trade
    for small-d_model archs whose per-layer all-reduces dominate (§Perf).
    """
    base = DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    names = set(mesh.axis_names)
    rules = dict(base.rules)
    strategy = getattr(cfg, "shard_strategy", "tp") if cfg is not None else "tp"
    if strategy == "dp_sp":
        for ax in ("heads", "kv_heads", "mlp", "vocab", "experts", "expert_mlp"):
            rules[ax] = None
        rules["seq"] = "model"
    elif strategy == "ep_only":
        # replicate the (small) attention/vocab weights, kill their per-layer
        # all-reduces; keep experts sharded — decode-collective trade (§Perf)
        for ax in ("heads", "kv_heads", "mlp", "vocab"):
            rules[ax] = None

    def keep(v):
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            return kept or None
        return v if v in names else None

    return ShardingRules(rules={k: keep(v) for k, v in rules.items()})


def logical_to_spec(axes_tree, rules: ShardingRules):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return compat.tree_map(
        lambda axes: rules.spec(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def shard_params_specs(axes_tree, mesh: Mesh, rules: ShardingRules | None = None):
    """NamedShardings for a params tree from its logical axes tree."""
    rules = rules or rules_for_mesh(mesh)
    specs = logical_to_spec(axes_tree, rules)
    return compat.tree_map(lambda s: NamedSharding(mesh, s), specs,
                           is_leaf=lambda x: isinstance(x, P))

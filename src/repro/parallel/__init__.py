from repro.parallel.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    logical_to_spec,
    shard_params_specs,
)

__all__ = ["ShardingRules", "DEFAULT_RULES", "logical_to_spec", "shard_params_specs"]

"""Sharded, MAC-verified, atomic checkpoints with elastic restore.

Fault-tolerance contract (designed for 1000+ nodes, exercised in tests):
  * atomic: write to `step_<n>.tmp/`, fsync, rename — a crash mid-save never
    corrupts the latest checkpoint;
  * integrity: every leaf file carries a ChaCha20-keyed polynomial MAC
    (paper's tamper/freshness model applied at rest); a flipped bit fails
    restore loudly;
  * sharded: leaves are saved as independent .npy blobs keyed by pytree path
    (on a real pod each host saves only its addressable shards — the layout
    here is the degenerate 1-host case of that scheme);
  * elastic: restore() takes the *target* shardings, so a checkpoint written
    on one mesh restores onto a different mesh shape (resharding happens via
    device_put against the new NamedShardings);
  * data-cursor: the input pipeline state (keystream counter, rng) rides
    along, so secure-ingest streams resume exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib

import numpy as np

import jax

from repro import compat
from repro.crypto.mac import mac_keys_from_keystream, mac_tag_host, mac_verify_host


class CheckpointError(RuntimeError):
    pass


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str, key: bytes = b"\x5c" * 32, keep: int = 3):
        self.dir = directory
        self.key = key
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _mac(self, path_label: str, arr: np.ndarray):
        kw = np.frombuffer(self.key, "<u4")
        nw = np.frombuffer(b"ckpt-mac----", "<u4")
        ctr = (zlib.crc32(path_label.encode()) ^ 0x5A5A) & 0x7FFFFFFF  # process-stable
        rs, ss = mac_keys_from_keystream(kw, nw, ctr)
        pad = (-arr.nbytes) % 4
        words = np.frombuffer(arr.tobytes() + b"\x00" * pad, "<u4")
        return rs, ss, words

    def save(self, step: int, tree, extra: dict | None = None):
        """Atomic sharded save of a pytree of arrays."""
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat = _flatten(compat.tree_map(lambda x: np.asarray(x), tree))
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for path, arr in flat.items():
            fname = path.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            rs, ss, words = self._mac(path, arr)
            tag = mac_tag_host(words, rs, ss)
            manifest["leaves"][path] = {
                "file": fname,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "mac": [int(t) for t in tag],
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def list_steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n[5:]))
        return sorted(out)

    def latest_step(self):
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the STRUCTURE of target_tree; `shardings` (same
        structure, NamedShardings) enables elastic restore onto a new mesh."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for path in flat_target:
            meta = manifest["leaves"].get(path)
            if meta is None:
                raise CheckpointError(f"missing leaf {path} in checkpoint {step}")
            arr = np.load(os.path.join(d, meta["file"]))
            rs, ss, words = self._mac(path, arr)
            if not mac_verify_host(words, rs, ss, np.array(meta["mac"], np.uint32)):
                raise CheckpointError(f"MAC mismatch for {path} — tampered/corrupt")
            if list(arr.shape) != list(np.shape(flat_target[path])):
                raise CheckpointError(
                    f"shape mismatch for {path}: ckpt {arr.shape} vs target "
                    f"{np.shape(flat_target[path])}"
                )
            sh = flat_shard.get(path)
            loaded[path] = jax.device_put(arr, sh) if sh is not None else arr

        def rebuild(tree, prefix=""):
            if isinstance(tree, dict):
                return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
            if isinstance(tree, (list, tuple)):
                t = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
                return type(tree)(t)
            return loaded[prefix[:-1]]

        return rebuild(target_tree), manifest["extra"]

"""Training step factory: loss/grads/AdamW update + secure batch ingest.

Secure ingest is the paper's data path applied to training: batches arrive
as ChaCha20 ciphertext (encrypted by the data pipeline on the host /
MapReduce splits) and are decrypted *inside* the jitted step — plaintext
tokens exist only in device memory ("inside the enclave"). The per-step
counter comes in-band so a restart resumes the keystream correctly.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.shuffle import SecureShuffleConfig
from repro.crypto.ctr import decrypt_array
from repro.models.lm import loss_fn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.parallel.sharding import logical_to_spec, rules_for_mesh


@dataclass(frozen=True)
class SecureIngest:
    """Session material for encrypted training batches (paper: k_data)."""

    key_words: Any
    nonce_words: Any


def _batch_specs(cfg, mesh, shape_kind="train"):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    specs = {"tokens": P(dp, None)}
    if cfg.family == "audio":
        specs["frames"] = P(dp, None, None)
    return specs


def make_train_step(
    cfg,
    mesh: Mesh,
    *,
    adamw: AdamWConfig = AdamWConfig(),
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    secure_ingest: SecureIngest | None = None,
    secure_moe: SecureShuffleConfig | None = None,
    accum_steps: int = 1,
    donate: bool = True,
):
    """Returns (train_step, param_specs, opt_specs, batch_specs).

    train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)
    `batch["tokens"]` is ciphertext (same shape/dtype) when secure_ingest is
    set; `batch["ctr"]` carries the keystream block offset for this step.
    `accum_steps > 1` scans microbatches (gradient accumulation): activation
    memory shrinks by the factor, grads average across microbatches.
    """
    from repro.models.lm import param_axes

    rules = rules_for_mesh(mesh, cfg)
    p_specs = logical_to_spec(param_axes(cfg), rules)
    batch_specs = _batch_specs(cfg, mesh)

    grad_fn = jax.value_and_grad(
        partial(loss_fn, cfg, mesh=mesh, secure_moe=secure_moe), has_aux=True
    )

    def step_fn(params, opt_state, batch, step):
        if secure_ingest is not None:
            ctr = batch["ctr"]
            batch = dict(batch)
            # decrypt inside the step: plaintext only in device memory
            batch["tokens"] = decrypt_array(
                batch["tokens"], secure_ingest.key_words, secure_ingest.nonce_words, ctr
            )
            if "frames" in batch:
                fr = batch["frames"]
                batch["frames"] = decrypt_array(
                    fr, secure_ingest.key_words, secure_ingest.nonce_words,
                    ctr + jnp.uint32(1 << 16),
                )
        batch = {k: v for k, v in batch.items() if k != "ctr"}

        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(gsum, mb):
                (l, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return gsum, (l, m)

            grads, (losses, ms) = jax.lax.scan(acc, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)), ms)

        lr = warmup_cosine(step, peak_lr=peak_lr, warmup=warmup, total=total_steps)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, lr, adamw)
        metrics = {"loss": loss, "lr": lr, **metrics, **opt_metrics}
        return params, opt_state, metrics

    # shardings ride in on the avals (NamedSharding-carrying ShapeDtypeStructs
    # in the dry-run; committed arrays in real training)
    train_step = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    return train_step, p_specs, batch_specs


def init_train_state(cfg, mesh, key, n_model: int | None = None):
    """Materialize sharded params + optimizer state on the mesh."""
    from repro.models.lm import init_params, param_axes

    rules = rules_for_mesh(mesh, cfg)
    p_specs = logical_to_spec(param_axes(cfg), rules)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), p_specs, is_leaf=lambda x: isinstance(x, P)
    )
    nm = n_model if n_model is not None else mesh.shape.get("model", 1)
    init = jax.jit(
        partial(init_params, cfg, n_model=nm), out_shardings=shardings
    )
    params = init(key)
    opt_state = jax.jit(
        adamw_init,
        out_shardings={"mu": shardings, "nu": shardings, "count": NamedSharding(mesh, P())},
    )(params)
    return params, opt_state

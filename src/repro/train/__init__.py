from repro.train.step import SecureIngest, make_train_step

__all__ = ["make_train_step", "SecureIngest"]

"""Keyed polynomial universal MAC over u32 lanes (Carter–Wegman style).

The paper's enclave paging verifies integrity + freshness of every fetched
page. Poly1305's 130-bit field does not map onto TPU integer units, so we use
an encrypt-then-MAC construction with a polynomial hash over GF(p), p = 2^31-1
(Mersenne), evaluated in pure u32 arithmetic (no x64 requirement): four
independent (r, s) pairs drawn from the ChaCha20 keystream give a 4×31-bit
tag. Structurally faithful (one-time authenticator keyed per message +
freshness counter in the associated data); documented in DESIGN.md as a
performance-shape stand-in, not a vetted primitive.

tag_j = ( sum_i m_i * r_j^(n-i) + s_j ) mod p          (Horner form)
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

P31 = (1 << 31) - 1
_MASK31 = jnp.uint32(P31)


def _mod31(x):
    """Reduce u32 (< 2^32) mod 2^31-1. Result < 2^31-1."""
    y = (x & _MASK31) + (x >> 31)
    return jnp.where(y >= _MASK31, y - _MASK31, y)


def _mulmod31(a, b):
    """(a*b) mod 2^31-1 with all intermediates in u32.

    a, b < 2^31. Split into 16-bit halves:
      a*b = a1*b1*2^32 + (a1*b0 + a0*b1)*2^16 + a0*b0
    2^31 ≡ 1 (mod p)  =>  2^32 ≡ 2,  x*2^16 handled by shift-reduction.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a0 = a & jnp.uint32(0xFFFF)
    a1 = a >> 16
    b0 = b & jnp.uint32(0xFFFF)
    b1 = b >> 16

    t3 = _mod31(a0 * b0)                      # a0*b0 < 2^32
    t2 = _mod31(_mod31(a1 * b0) + _mod31(a0 * b1))
    t1 = _mod31(a1 * b1)                      # < 2^30

    # t1 * 2^32 ≡ t1 * 2
    c1 = _mod31(t1 + t1)
    # t2 * 2^16: (x << 16) mod p = ((x << 16) & mask) + (x >> 15)
    c2 = _mod31(((t2 << 16) & _MASK31) + (t2 >> 15))
    return _mod31(_mod31(c1 + c2) + t3)


def mac_tag_words(words: jax.Array, rs: jax.Array, ss: jax.Array) -> jax.Array:
    """Tag a (n,) u32 message with 4 lanes. rs, ss: (4,) u32 (< p, from keystream).

    jit-safe (runs "inside the enclave"). Returns (4,) u32 tag.
    """
    words = words.reshape(-1).astype(jnp.uint32)
    # message words reduced into the field; prepend length word to prevent
    # extension across sizes.
    n = jnp.uint32(words.shape[0])
    msg = jnp.concatenate([jnp.array([n], jnp.uint32), words])
    msg = _mod31(msg)

    def horner(h, m):
        # h: (4,), m scalar broadcast over lanes
        h = _mulmod31(h, rs)
        h = _mod31(h + m)
        return h, None

    h0 = jnp.zeros((4,), jnp.uint32)
    h, _ = jax.lax.scan(lambda h, m: horner(h, m), h0, msg)
    return _mod31(h + _mod31(ss))


# ---------------------------------------------------------------------------
# numpy host path — identical tags (cross-checked in tests)
# ---------------------------------------------------------------------------


def mac_tag_host(words: np.ndarray, rs: np.ndarray, ss: np.ndarray) -> np.ndarray:
    """Block-vectorized Horner (identical tags to the word-at-a-time form:
    leading zero words contribute nothing to the polynomial)."""
    words = np.asarray(words, dtype=np.uint64).reshape(-1)
    rs = np.asarray(rs, dtype=np.uint64) % np.uint64(P31)
    ss = np.asarray(ss, dtype=np.uint64)
    p = np.uint64(P31)
    msg = np.concatenate([np.array([len(words)], np.uint64), words]) % p

    blk = 64
    pad = (-len(msg)) % blk
    if pad:
        msg = np.concatenate([np.zeros(pad, np.uint64), msg])
    msg = msg.reshape(-1, blk)  # (n_blocks, blk)

    # rp[l, j] = rs[l]^(blk-1-j) mod p ;  r_blk = rs^blk mod p
    rp = np.empty((4, blk), np.uint64)
    rp[:, blk - 1] = 1
    for j in range(blk - 2, -1, -1):
        rp[:, j] = (rp[:, j + 1] * rs) % p
    r_blk = (rp[:, 0] * rs) % p

    h = np.zeros(4, np.uint64)
    for row in msg:
        acc = ((row[None, :] * rp) % p).sum(axis=1) % p  # < 2^31·blk, fits u64
        h = (h * r_blk + acc) % p
    return ((h + ss % p) % p).astype(np.uint32)


def mac_verify_host(words: np.ndarray, rs, ss, tag) -> bool:
    return bool(np.all(mac_tag_host(words, rs, ss) == np.asarray(tag, np.uint32)))


def mac_keys_from_keystream(key_words, nonce_words, counter0):
    """Derive (rs, ss) from one keystream block (host-side numpy)."""
    from repro.crypto.chacha import _chacha20_blocks_np  # local import, host path

    blk = _chacha20_blocks_np(
        np.asarray(key_words, np.uint32),
        np.array([counter0], np.uint32),
        np.asarray(nonce_words, np.uint32),
    )[0]
    rs = blk[:4] % np.uint32(P31)
    ss = blk[4:8] % np.uint32(P31)
    return rs, ss

"""Key hierarchy + simulated attestation / session establishment.

The paper leaves key provisioning to its SCBR predecessor [12]: subscriptions
and publication *headers* use one key, payloads another, and enclaves receive
keys after (SGX remote) attestation. We keep the protocol flow and simulate
the hardware quote:

  master key (client / data owner)
    ├── k_header   — pub/sub headers + subscriptions (router enclave key)
    ├── k_code     — map/reduce code payloads (worker enclave key)
    ├── k_data     — data split payloads
    ├── k_shuffle  — mapper→reducer traffic
    └── k_page     — SecurePager page encryption + MAC

Derivation is a ChaCha20-as-PRF expand: subkey = keystream(master,
nonce=H(label), counter=0)[:32], i.e. HKDF-expand shape with the block
function as PRF. Workers "attest" by presenting a measurement (a hash of
their code identity); the client releases wrapped session keys only for
expected measurements — `Attestation.verify` is where a real SGX quote check
would sit.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from repro.crypto.chacha import chacha20_encrypt_bytes, key_to_words, nonce_to_words

LABELS = ("header", "code", "data", "shuffle", "page", "aggregate")


def _label_nonce(label: str) -> bytes:
    return hashlib.sha256(b"repro.kdf:" + label.encode()).digest()[:12]


def derive_key(master: bytes, label: str) -> bytes:
    """Derive a 32-byte subkey from `master` for `label` (ChaCha20 PRF expand)."""
    if len(master) != 32:
        raise ValueError("master key must be 32 bytes")
    return chacha20_encrypt_bytes(master, _label_nonce(label), 0, b"\x00" * 32)


@dataclass(frozen=True)
class SessionKeys:
    """Per-job session keys, as word arrays ready for in-graph use."""

    header: bytes
    code: bytes
    data: bytes
    shuffle: bytes
    page: bytes
    aggregate: bytes

    def words(self, label: str) -> np.ndarray:
        return key_to_words(getattr(self, label))

    @staticmethod
    def nonce(label: str, stream: int = 0) -> bytes:
        """Deterministic per-(label, stream) nonce; stream = split/worker id."""
        return hashlib.sha256(f"repro.nonce:{label}:{stream}".encode()).digest()[:12]

    @staticmethod
    def nonce_words(label: str, stream: int = 0) -> np.ndarray:
        return nonce_to_words(SessionKeys.nonce(label, stream))


def make_session_keys(master: bytes) -> SessionKeys:
    return SessionKeys(**{lbl: derive_key(master, lbl) for lbl in LABELS})


@dataclass
class Attestation:
    """Simulated SGX attestation: measurement check gates key release."""

    expected_measurements: set = field(default_factory=set)

    @staticmethod
    def measure(code_identity: bytes) -> str:
        return hashlib.sha256(b"MRENCLAVE:" + code_identity).hexdigest()

    def enroll(self, code_identity: bytes) -> str:
        m = self.measure(code_identity)
        self.expected_measurements.add(m)
        return m

    def verify(self, measurement: str) -> bool:
        # A real deployment verifies an SGX quote (EPID/DCAP) here.
        return measurement in self.expected_measurements


@dataclass
class KeyHierarchy:
    """Client-held master key + attestation-gated session key release."""

    master: bytes
    attestation: Attestation = field(default_factory=Attestation)

    def __post_init__(self):
        if len(self.master) != 32:
            raise ValueError("master key must be 32 bytes")
        self.session = make_session_keys(self.master)

    def release_keys(self, measurement: str) -> SessionKeys:
        if not self.attestation.verify(measurement):
            raise PermissionError(f"attestation failed for measurement {measurement[:16]}…")
        return self.session

    def wrap_key(self, label: str, worker_kek: bytes) -> bytes:
        """Key-wrap a session key under a worker's KEK (transport form)."""
        nonce = SessionKeys.nonce("wrap:" + label)
        return chacha20_encrypt_bytes(worker_kek, nonce, 0, getattr(self.session, label))

    @staticmethod
    def unwrap_key(label: str, worker_kek: bytes, wrapped: bytes) -> bytes:
        nonce = SessionKeys.nonce("wrap:" + label)
        return chacha20_encrypt_bytes(worker_kek, nonce, 0, wrapped)

"""Cryptographic substrate: ChaCha20-CTR stream cipher, universal MAC, keys.

The paper uses AES-CTR-128 on AES-NI hardware. TPUs have no AES analogue
(byte-table S-boxes are gather-hostile), so the cipher is ChaCha20 (RFC 8439):
an ARX design that maps 1:1 onto 32-bit integer vector lanes. The CTR security
model (keystream XOR, nonce+counter uniqueness) is identical.

Two implementations, cross-checked in tests:
  * `chacha` — vectorized jnp (in-graph, differentiably opaque) + numpy host path
  * `kernels/chacha20` — the Pallas TPU kernel (validated in interpret mode)
"""

from repro.crypto.chacha import (
    chacha20_block_words,
    chacha20_encrypt_bytes,
    chacha20_keystream_words,
    key_to_words,
    nonce_to_words,
)
from repro.crypto.ctr import decrypt_array, decrypt_tree, encrypt_array, encrypt_tree
from repro.crypto.mac import mac_tag_host, mac_tag_words, mac_verify_host
from repro.crypto.keys import KeyHierarchy, SessionKeys, derive_key

__all__ = [
    "chacha20_block_words",
    "chacha20_encrypt_bytes",
    "chacha20_keystream_words",
    "key_to_words",
    "nonce_to_words",
    "encrypt_array",
    "decrypt_array",
    "encrypt_tree",
    "decrypt_tree",
    "mac_tag_words",
    "mac_tag_host",
    "mac_verify_host",
    "KeyHierarchy",
    "SessionKeys",
    "derive_key",
]

"""ChaCha20 (RFC 8439) — vectorized JAX implementation + numpy host path.

State (16 u32 words):
    0..3   constants "expa" "nd 3" "2-by" "te k"
    4..11  key (8 words, little-endian)
    12     block counter
    13..15 nonce (3 words, little-endian)

`chacha20_block_words` is the pure-jnp oracle for the Pallas kernel
(`repro.kernels.chacha20`), and the workhorse for in-graph encryption.
The numpy variant (`_np` suffix) serves host-side message encryption in the
pub/sub layer; both are checked against the RFC 8439 test vectors.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

CONSTANT_WORDS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)

# Quarter-round schedule: 4 column rounds then 4 diagonal rounds.
_QR_SCHEDULE = (
    (0, 4, 8, 12),
    (1, 5, 9, 13),
    (2, 6, 10, 14),
    (3, 7, 11, 15),
    (0, 5, 10, 15),
    (1, 6, 11, 12),
    (2, 7, 8, 13),
    (3, 4, 9, 14),
)


def key_to_words(key: bytes) -> np.ndarray:
    """32-byte key -> (8,) u32 little-endian words."""
    if len(key) != 32:
        raise ValueError(f"ChaCha20 key must be 32 bytes, got {len(key)}")
    return np.frombuffer(key, dtype="<u4").copy()


def nonce_to_words(nonce: bytes) -> np.ndarray:
    """12-byte nonce -> (3,) u32 little-endian words."""
    if len(nonce) != 12:
        raise ValueError(f"ChaCha20 nonce must be 12 bytes, got {len(nonce)}")
    return np.frombuffer(nonce, dtype="<u4").copy()


# ---------------------------------------------------------------------------
# jnp implementation (vectorized over blocks)
# ---------------------------------------------------------------------------


def _rotl(x, n: int):
    return (x << n) | (x >> (32 - n))


def _double_round(xs):
    for a, b, c, d in _QR_SCHEDULE:
        xa, xb, xc, xd = xs[a], xs[b], xs[c], xs[d]
        xa = xa + xb
        xd = _rotl(xd ^ xa, 16)
        xc = xc + xd
        xb = _rotl(xb ^ xc, 12)
        xa = xa + xb
        xd = _rotl(xd ^ xa, 8)
        xc = xc + xd
        xb = _rotl(xb ^ xc, 7)
        xs[a], xs[b], xs[c], xs[d] = xa, xb, xc, xd
    return xs


def chacha20_block_words(key_words, counters, nonce_words):
    """Vectorized ChaCha20 block function.

    Args:
      key_words:   (8,)  u32
      counters:    (B,)  u32 — one block counter per output block
      nonce_words: (3,)  u32

    Returns: (B, 16) u32 keystream words (little-endian serialization order).
    """
    key_words = jnp.asarray(key_words, dtype=jnp.uint32)
    counters = jnp.asarray(counters, dtype=jnp.uint32)
    nonce_words = jnp.asarray(nonce_words, dtype=jnp.uint32)
    b = counters.shape[0]

    init = []
    for w in CONSTANT_WORDS:
        init.append(jnp.full((b,), w, dtype=jnp.uint32))
    for i in range(8):
        init.append(jnp.broadcast_to(key_words[i], (b,)))
    init.append(counters)
    for i in range(3):
        init.append(jnp.broadcast_to(nonce_words[i], (b,)))

    xs = list(init)
    for _ in range(10):
        xs = _double_round(xs)
    out = [x + x0 for x, x0 in zip(xs, init)]
    return jnp.stack(out, axis=-1)  # (B, 16)


def chacha20_keystream_words(key_words, nonce_words, counter0, n_words: int):
    """Keystream of `n_words` u32 words starting at block counter `counter0`."""
    n_blocks = -(-n_words // 16)
    counters = jnp.uint32(counter0) + jnp.arange(n_blocks, dtype=jnp.uint32)
    ks = chacha20_block_words(key_words, counters, nonce_words)
    return ks.reshape(-1)[:n_words]


# ---------------------------------------------------------------------------
# numpy host path (pub/sub wire encryption; no device involvement)
# ---------------------------------------------------------------------------


def _chacha20_blocks_np(key_words: np.ndarray, counters: np.ndarray, nonce_words: np.ndarray) -> np.ndarray:
    b = counters.shape[0]
    xs = np.empty((16, b), dtype=np.uint32)
    for i, w in enumerate(CONSTANT_WORDS):
        xs[i] = w
    for i in range(8):
        xs[4 + i] = key_words[i]
    xs[12] = counters
    for i in range(3):
        xs[13 + i] = nonce_words[i]
    init = xs.copy()

    def rotl(x, n):
        return (x << np.uint32(n)) | (x >> np.uint32(32 - n))

    with np.errstate(over="ignore"):
        for _ in range(10):
            for a, bq, c, d in _QR_SCHEDULE:
                xs[a] += xs[bq]
                xs[d] = rotl(xs[d] ^ xs[a], 16)
                xs[c] += xs[d]
                xs[bq] = rotl(xs[bq] ^ xs[c], 12)
                xs[a] += xs[bq]
                xs[d] = rotl(xs[d] ^ xs[a], 8)
                xs[c] += xs[d]
                xs[bq] = rotl(xs[bq] ^ xs[c], 7)
        xs += init
    return xs.T  # (B, 16)


def chacha20_encrypt_bytes(key: bytes, nonce: bytes, counter0: int, data: bytes) -> bytes:
    """Host-side ChaCha20-CTR over raw bytes (encrypt == decrypt)."""
    kw = key_to_words(key)
    nw = nonce_to_words(nonce)
    n = len(data)
    n_blocks = -(-n // 64) if n else 0
    if n_blocks == 0:
        return b""
    counters = (np.uint32(counter0) + np.arange(n_blocks, dtype=np.uint32)).astype(np.uint32)
    ks = _chacha20_blocks_np(kw, counters, nw)  # (B, 16) u32
    ks_bytes = ks.astype("<u4").tobytes()[:n]
    buf = np.frombuffer(data, dtype=np.uint8) ^ np.frombuffer(ks_bytes, dtype=np.uint8)
    return buf.tobytes()

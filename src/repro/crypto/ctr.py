"""Counter-mode encryption of JAX arrays and pytrees.

Arbitrary-dtype arrays are bitcast to unsigned words, widened to a u32 stream,
XORed with the ChaCha20 keystream, and narrowed back. Encryption and
decryption are the same XOR; both directions are jit-safe so ciphertext can be
decrypted *inside* a compiled step ("inside the enclave") — the paper's model
of data that is plaintext only within the trusted boundary.

Counter-space layout: every logical payload gets a distinct (nonce, counter0)
pair from `repro.crypto.keys`; within a payload, block counters increase
sequentially. Pytrees allocate disjoint counter ranges per leaf so the whole
tree is one logical message.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.chacha import chacha20_keystream_words

_UINT_FOR_WIDTH = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def words_for(shape, dtype) -> int:
    """Number of u32 keystream words needed to cover an array."""
    nbytes = math.prod(shape) * jnp.dtype(dtype).itemsize
    return -(-nbytes // 4)


def pad_for(shape, dtype) -> int:
    """Static narrow-element pad count `_to_words` will use for this shape."""
    width = jnp.dtype(dtype).itemsize
    if width >= 4:
        return 0
    per = 4 // width
    return (-math.prod(shape)) % per


def _to_words(x: jax.Array):
    """Bitcast + pack an arbitrary array into a (n_words,) u32 stream."""
    dt = x.dtype
    width = dt.itemsize
    if width == 8:
        # 64-bit types: view as pairs of u32 via bitcast to u32 with trailing dim.
        u = jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)
        return u, 0
    u = jax.lax.bitcast_convert_type(x, _UINT_FOR_WIDTH[width]).reshape(-1)
    if width == 4:
        return u, 0
    per = 4 // width
    pad = (-u.shape[0]) % per
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), u.dtype)])
    u = u.reshape(-1, per).astype(jnp.uint32)
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(8 * width)
    words = (u << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)
    return words, pad


def _from_words(words: jax.Array, shape, dtype, pad: int):
    dt = jnp.dtype(dtype)
    width = dt.itemsize
    if width == 8:
        u = words.reshape(tuple(shape) + (2,))
        return _bitcast64(u, dt, shape)
    if width == 4:
        u = words.reshape(shape) if dt == jnp.uint32 else jax.lax.bitcast_convert_type(words, dt).reshape(shape)
        return u
    per = 4 // width
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(8 * width)
    narrow = ((words[:, None] >> shifts[None, :]) & jnp.uint32((1 << (8 * width)) - 1)).astype(
        _UINT_FOR_WIDTH[width]
    )
    narrow = narrow.reshape(-1)
    if pad:
        narrow = narrow[:-pad]
    return jax.lax.bitcast_convert_type(narrow.reshape(shape), dt) if dt != narrow.dtype else narrow.reshape(shape)


def _bitcast64(u32_pairs, dt, shape):
    # (..., 2) u32 -> 64-bit dtype. bitcast_convert_type collapses the
    # trailing dimension when converting to a wider type.
    return jax.lax.bitcast_convert_type(u32_pairs, dt).reshape(shape)


def encrypt_array(x: jax.Array, key_words, nonce_words, counter0) -> jax.Array:
    """XOR `x` with the ChaCha20 keystream; returns array of same shape/dtype.

    jit-safe. `counter0` may be a traced scalar (freshness counters).
    """
    shape, dtype = x.shape, x.dtype
    words, pad = _to_words(x)
    ks = chacha20_keystream_words(key_words, nonce_words, counter0, words.shape[0])
    return _from_words(words ^ ks, shape, dtype, pad)


decrypt_array = encrypt_array  # CTR: same operation


def encrypt_tree(tree: Any, key_words, nonce_words, counter0=0):
    """Encrypt every leaf with disjoint counter ranges. Returns (tree, n_blocks).

    The same call decrypts (XOR). Counter ranges are assigned in pytree order,
    so both sides derive identical layouts from the structure alone.
    """
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    ctr = counter0
    for leaf in leaves:
        leaf = jnp.asarray(leaf)
        out.append(encrypt_array(leaf, key_words, nonce_words, ctr))
        ctr = ctr + (-(-words_for(leaf.shape, leaf.dtype) // 16))
    return jax.tree.unflatten(treedef, out), ctr


decrypt_tree = encrypt_tree


def tree_counter_blocks(tree: Any) -> int:
    """Total counter blocks a pytree consumes (for counter-space bookkeeping)."""
    leaves = jax.tree.leaves(tree)
    return sum(-(-words_for(np.shape(l), np.result_type(l)) // 16) for l in leaves)

"""The paper's MapReduce session protocol over SCBR (Figs. 3-4).

Session establishment:
  1. worker  --SUB(JOB_OPENING)-------------------> router
  2. client  --SUB(JOB_DETAILS)-------------------> router
  3. client  --PUB JOB_OPENING {job}--------------> available workers
  4. worker  --PUB JOB_DETAILS {role, subs for code+data}--> client
  5. client hires: registers the worker's code/data subscriptions on its
     behalf, fixing the mapper/reducer roster.

Provisioning:
  6. client  --PUB MAP_CODETYPE {n_reducers} + Lua/SecVM/callable code-->
     mappers;    REDUCE_CODETYPE {n_mappers} --> reducers
  7. client  --PUB MAP_DATATYPE {dest, split_id} + rows--> mapper `dest`
  8. mappers --PUB REDUCE_DATATYPE {dest=hash(k)%R, split_id}--> reducers
  9. mappers --PUB MAP_EOS {slot}--> all reducers (count to n_mappers)
 10. reducers --PUB RESULT--> client
"""

from __future__ import annotations

from repro.pubsub.messages import Message, Subscription

JOB_OPENING = "JOB_OPENING"
JOB_DETAILS = "JOB_DETAILS"
MAP_CODETYPE = "MAP_CODETYPE"
REDUCE_CODETYPE = "REDUCE_CODETYPE"
MAP_DATATYPE = "MAP_DATATYPE"
REDUCE_DATATYPE = "REDUCE_DATATYPE"
MAP_EOS = "MAP_EOS"
RESULT = "RESULT"
HEARTBEAT = "HEARTBEAT"


def sub_job_openings(worker: str) -> Subscription:
    return Subscription(constraints=(("type", "==", JOB_OPENING),), subscriber=worker)


def sub_job_details(client: str, job_id: str) -> Subscription:
    return Subscription(
        constraints=(("type", "==", JOB_DETAILS), ("job", "==", job_id)), subscriber=client
    )


def sub_code(worker: str, job_id: str, role: str) -> Subscription:
    code_type = MAP_CODETYPE if role == "mapper" else REDUCE_CODETYPE
    return Subscription(
        constraints=(("type", "==", code_type), ("job", "==", job_id), ("dest", "==", worker)),
        subscriber=worker,
    )


def sub_data(worker: str, job_id: str, role: str) -> Subscription:
    data_type = MAP_DATATYPE if role == "mapper" else REDUCE_DATATYPE
    return Subscription(
        constraints=(("type", "==", data_type), ("job", "==", job_id), ("dest", "==", worker)),
        subscriber=worker,
    )


def sub_eos(worker: str, job_id: str) -> Subscription:
    return Subscription(
        constraints=(("type", "==", MAP_EOS), ("job", "==", job_id)), subscriber=worker
    )


def sub_results(client: str, job_id: str) -> Subscription:
    return Subscription(
        constraints=(("type", "==", RESULT), ("job", "==", job_id)), subscriber=client
    )


def sub_heartbeats(client: str) -> Subscription:
    return Subscription(constraints=(("type", "==", HEARTBEAT),), subscriber=client)

"""Wire format: encrypted headers/subscriptions + separately-keyed payloads.

Paper §III: "All subscriptions and publication messages are encrypted using a
symmetric cypher while outside the SGX enclaves. The subscriptions and
publication headers are decrypted inside the enclave, where subscriptions are
stored. Then, the service routes the publication payloads (encrypted with a
different key) to matching subscribers."

Headers are flat string->(str|int) dicts serialized as JSON; subscriptions
are conjunctions of (field, op, value) constraints, op in {==, !=, <, <=, >,
>=, exists}. Every wire blob carries a 4-byte counter prefix used as the CTR
nonce stream id, so no two messages reuse a keystream.
"""

from __future__ import annotations

import itertools
import json
from dataclasses import dataclass, field
from typing import Any

from repro.crypto.chacha import chacha20_encrypt_bytes
from repro.crypto.keys import SessionKeys

_WIRE_SEQ = itertools.count(1)

OPS = {"==", "!=", "<", "<=", ">", ">=", "exists"}


def _seal(key: bytes, label: str, obj_bytes: bytes) -> bytes:
    seq = next(_WIRE_SEQ)
    nonce = SessionKeys.nonce(label, seq)
    ct = chacha20_encrypt_bytes(key, nonce, 0, obj_bytes)
    return seq.to_bytes(8, "little") + ct


def _open(key: bytes, label: str, blob: bytes) -> bytes:
    seq = int.from_bytes(blob[:8], "little")
    nonce = SessionKeys.nonce(label, seq)
    return chacha20_encrypt_bytes(key, nonce, 0, blob[8:])


@dataclass(frozen=True)
class Subscription:
    """Conjunction of constraints over header fields."""

    constraints: tuple  # ((field, op, value), ...)
    subscriber: str
    sub_id: int = 0

    def matches(self, header: dict) -> bool:
        for f, op, v in self.constraints:
            if op == "exists":
                if f not in header:
                    return False
                continue
            if f not in header:
                return False
            h = header[f]
            try:
                ok = {
                    "==": h == v,
                    "!=": h != v,
                    "<": h < v,
                    "<=": h <= v,
                    ">": h > v,
                    ">=": h >= v,
                }[op]
            except TypeError:
                return False
            if not ok:
                return False
        return True

    def seal(self, header_key: bytes) -> bytes:
        obj = {"c": list(self.constraints), "s": self.subscriber, "i": self.sub_id}
        return _seal(header_key, "sub", json.dumps(obj).encode())

    @staticmethod
    def unseal(header_key: bytes, blob: bytes) -> "Subscription":
        obj = json.loads(_open(header_key, "sub", blob))
        return Subscription(
            constraints=tuple(tuple(c) for c in obj["c"]),
            subscriber=obj["s"],
            sub_id=obj["i"],
        )


@dataclass
class Message:
    """A publication: encrypted header + separately-encrypted payload."""

    header_ct: bytes
    payload_ct: bytes
    sender: str = ""

    @staticmethod
    def seal(header: dict, payload: bytes, header_key: bytes, payload_key: bytes,
             sender: str = "") -> "Message":
        hct = _seal(header_key, "hdr", json.dumps(header).encode())
        pct = _seal(payload_key, "pay", payload)
        return Message(header_ct=hct, payload_ct=pct, sender=sender)

    def open_header(self, header_key: bytes) -> dict:
        return json.loads(_open(header_key, "hdr", self.header_ct))

    def open_payload(self, payload_key: bytes) -> bytes:
        return _open(payload_key, "pay", self.payload_ct)

    @property
    def wire_bytes(self) -> int:
        return len(self.header_ct) + len(self.payload_ct)

"""The SCBR routing engine.

The router's matching runs "inside the enclave": it holds the header key,
decrypts subscriptions/headers there, and forwards *payloads it cannot read*
(payload key never enters the router). Delivery is via per-subscriber
outboxes drained by the runtime simulator.

The paper notes the centralized router is the scalability limit and cites
StreamHub/elastic-scaling [16,17]; `shard_hint` reproduces that design note:
routers can be replicated per header-field shard.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

from repro.pubsub.messages import Message, Subscription


@dataclass
class RouterStats:
    publications: int = 0
    deliveries: int = 0
    subscriptions: int = 0
    wire_bytes: int = 0
    match_checks: int = 0


class ScbrRouter:
    """Content-based matcher with enclave-held header key."""

    def __init__(self, header_key: bytes, name: str = "scbr"):
        self._header_key = header_key  # lives only "inside the enclave"
        self.name = name
        self._subs: dict[int, Subscription] = {}
        self._next_id = 1
        self.outboxes: dict[str, list] = defaultdict(list)
        self.stats = RouterStats()

    # -- subscription management (encrypted on the wire) ----------------------

    def subscribe(self, sub_ct: bytes) -> int:
        sub = Subscription.unseal(self._header_key, sub_ct)  # decrypt in enclave
        sid = self._next_id
        self._next_id += 1
        self._subs[sid] = sub
        self.stats.subscriptions += 1
        self.stats.wire_bytes += len(sub_ct)
        return sid

    def unsubscribe(self, sid: int):
        self._subs.pop(sid, None)

    def unsubscribe_all(self, subscriber: str):
        for sid in [s for s, sub in self._subs.items() if sub.subscriber == subscriber]:
            del self._subs[sid]

    # -- publication -----------------------------------------------------------

    def publish(self, msg: Message) -> list[str]:
        header = msg.open_header(self._header_key)  # decrypt in enclave
        targets = []
        for sub in list(self._subs.values()):
            self.stats.match_checks += 1
            if sub.matches(header) and sub.subscriber != msg.sender:
                targets.append(sub.subscriber)
        # payload forwarded still-encrypted; router never holds its key
        for t in dict.fromkeys(targets):
            self.outboxes[t].append(msg)
            self.stats.deliveries += 1
        self.stats.publications += 1
        self.stats.wire_bytes += msg.wire_bytes
        return list(dict.fromkeys(targets))

    def drain(self, subscriber: str) -> list[Message]:
        out = self.outboxes[subscriber]
        self.outboxes[subscriber] = []
        return out

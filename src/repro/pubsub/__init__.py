"""SCBR — secure content-based routing (the paper's pub/sub substrate [12]).

Subscriptions and publication headers are encrypted on the wire and matched
only inside the router's "enclave"; payloads are encrypted under a different
key and are opaque to the router. The MapReduce session-establishment and
provisioning protocols (paper Figs. 3-4) live in `protocol.py`.
"""

from repro.pubsub.messages import Message, Subscription
from repro.pubsub.router import ScbrRouter

__all__ = ["Message", "Subscription", "ScbrRouter"]

"""Persistent-mesh secure job service: bucketed runner cache + batched admission.

The paper's deployment model is a long-lived cluster: the enclave session is
established once and MANY jobs flow through it. The repo's entry points
(`kmeans_fit`, `sample_sort`, `grep_count`) instead pay per-call setup — a
fresh runner dict, a fresh trace, a fresh XLA compile — which on the secure
path dwarfs the job itself (compiles are tens of seconds; a converged fit is
milliseconds). This module makes the session persistent:

  * `RunnerCache` — ONE process-wide compile cache, keyed by
    (workload spec identity x padded input bucket x chunk size x knob tuple:
    chacha impl / wire coalescing / state mode / halt loop / donation /
    secure key material). It replaces the ad-hoc per-call `runners` dict of
    `core/driver.py::run_until` through the driver's duck-typed
    `get_or_build(n_rounds, build)` contract (see the driver's Serving
    section), counts hits / misses / evictions, and bounds residency with
    LRU eviction ($REPRO_SERVICE_MAX_RUNNERS).

  * GEOMETRIC SIZE BUCKETS — `bucket_for` rounds every job's input length up
    a fixed geometric ladder (x`$REPRO_BUCKET_GROWTH`, default 2, aligned to
    the mesh), so a job of size 1.1xN pads to the same 2xN bucket an earlier
    job compiled and REUSES its program instead of recompiling. Padding is
    inert by construction in each workload: k-means pads zero-weight rows
    (contribute nothing), sort pads +inf (non-finite records are marked
    invalid and never shuffled), grep pads -1 tokens (match no pattern).

  * `SecureJobService` — owns one mesh + one `SecureShuffleConfig` for its
    lifetime and serves concurrent k-means / sort / grep jobs. `submit_*()`
    returns a future-backed `JobHandle` immediately; a single scheduler
    thread admits queued jobs into free concurrency slots and round-robins
    ONE adaptive chunk per job per pass through the driver's cooperative
    `run_until_chunks` generators, so a long job cannot head-of-line block
    a short one. Interleaving is bit-identical to serial execution: each
    suspended generator owns its carried state, and every job draws from a
    provably disjoint keystream range — admission assigns each job a round
    BASE from a monotone counter advanced by its `max_rounds` budget
    (`round_offset` disjointness contract, `core/driver.py`).

`benchmarks/bench_service.py` measures the payoff (cold vs warm submit
latency, hit rate, throughput vs queue depth) and `runtime/sim.py`'s
`AdmissionSim` replays arrival traces against the cost model to compare
admission policies without touching a device.
"""

from __future__ import annotations

import math
import os
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.driver import (
    DEFAULT_HALT_LOOP,
    resolve_state_mode,
    run_until_chunks,
)
from repro.core.grep import make_grep_spec
from repro.core.kmeans import make_kmeans_iterative_spec
from repro.core.shuffle import (
    SecureShuffleConfig,
    resolve_chacha_impl,
    resolve_coalesce,
)
from repro.core.sort import make_sample_sort_spec

BUCKET_GROWTH_ENV = "REPRO_BUCKET_GROWTH"
MAX_RUNNERS_ENV = "REPRO_SERVICE_MAX_RUNNERS"


def _model_recommendation(knob: str, **ctx):
    """Calibrated-model answer for an `auto` knob, or None when no
    calibration is active (see `core/shuffle.py::_model_recommendation`)."""
    from repro.perf.model import recommendation

    return recommendation(knob, **ctx)


def resolve_bucket_growth(growth=None) -> float:
    """Resolve the geometric bucket-ladder growth factor (a float > 1).

    None/'auto' defers to $REPRO_BUCKET_GROWTH, then to the calibrated cost
    model when one is active (the factor minimizing AdmissionSim makespan
    under the calibrated TimingModel; `repro/perf/model.py`), then to the
    default 2.0 — power-of-two buckets; an explicit number always wins over
    the environment. Smaller factors waste less padding per job but compile
    more distinct buckets; the trade is measured by
    `runtime/sim.py::AdmissionSim`.
    """
    from_env = False
    if growth in (None, "auto"):
        env_val = os.environ.get(BUCKET_GROWTH_ENV)
        if env_val is None:
            rec = _model_recommendation("bucket_growth")
            if rec is None:
                return 2.0
            growth = rec
        else:
            growth, from_env = env_val.strip(), True
    try:
        val = float(growth)
    except (TypeError, ValueError):
        val = float("nan")
    if not val > 1.0:
        if from_env:
            raise ValueError(
                f"invalid ${BUCKET_GROWTH_ENV}={growth!r} in the environment: "
                f"bucket growth must be a number > 1 "
                f"(unset ${BUCKET_GROWTH_ENV} to use the default 2.0)")
        raise ValueError(
            f"bucket growth must be a number > 1 or 'auto', got {growth!r}")
    return val


def resolve_max_resident(limit="auto") -> int | None:
    """Resolve the runner-cache residency cap (int >= 1, or None = unbounded).

    'auto' defers to $REPRO_SERVICE_MAX_RUNNERS, then to the calibrated
    cost model when one is active (which answers 'unbounded' — evictions
    only ever add recompiles; `repro/perf/model.py`), then to the default
    unbounded (0 or 'none' mean unbounded explicitly); an explicit int/None
    always wins over the environment. The cap bounds how many compiled
    runner programs stay resident — the LRU loser is evicted (and its
    compiles with it).
    """
    from_env = False
    if limit == "auto":
        env_val = os.environ.get(MAX_RUNNERS_ENV)
        if env_val is None:
            rec = _model_recommendation("max_resident")
            if rec is None or rec == "unbounded":
                return None
            limit = rec
        else:
            limit, from_env = env_val.strip().lower(), True
    if limit in ("none", "unbounded", "0"):
        return None
    if limit is None:
        return None
    try:
        val = int(limit)
    except (TypeError, ValueError):
        val = 0
    if val < 1:
        if from_env:
            raise ValueError(
                f"invalid ${MAX_RUNNERS_ENV}={limit!r} in the environment: "
                f"the resident-runner cap must be an integer >= 1, or "
                f"0/'none' for unbounded "
                f"(unset ${MAX_RUNNERS_ENV} to use the default unbounded)")
        raise ValueError(
            f"max_resident must be an integer >= 1, None, or 'auto', "
            f"got {limit!r}")
    return val


def bucket_for(n: int, *, multiple: int = 1, growth=None) -> int:
    """Round `n` up to the geometric bucket ladder.

    The ladder starts at `multiple` (the mesh-alignment unit — every bucket
    must divide evenly over the shards) and each rung is the previous one
    x`growth`, rounded up to the next `multiple`. The rungs depend only on
    (multiple, growth), never on `n`, so every job size in (rung_{i-1},
    rung_i] lands on the SAME rung and shares its compiled programs.
    """
    growth = resolve_bucket_growth(growth)
    if n < 1:
        raise ValueError(f"bucket_for needs n >= 1, got {n}")
    if multiple < 1:
        raise ValueError(f"bucket_for needs multiple >= 1, got {multiple}")
    b = multiple
    while b < n:
        # strictly increasing even when growth barely clears the alignment
        b = max(int(math.ceil(b * growth / multiple)) * multiple, b + multiple)
    return b


def _mesh_token(mesh: Mesh):
    return (tuple(mesh.shape.items()),
            tuple(d.id for d in np.asarray(mesh.devices).flat))


def _secure_token(secure: SecureShuffleConfig | None,
                  chacha_impl, coalesce) -> tuple:
    """Hashable identity of the secure wire a runner was traced against.

    Key/nonce material is baked into the traced program's closure (the
    driver's runner-cache contract), so it MUST key the cache: two sessions
    with different keys can never share a compiled runner. Impl/coalesce are
    resolved here so 'auto' (environment-dependent) never aliases a concrete
    choice.
    """
    if secure is None:
        return ("plain", resolve_coalesce(coalesce if coalesce is not None
                                          else "auto"))
    secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
    impl, interpret = resolve_chacha_impl(secure.impl)
    return (
        np.asarray(secure.key_words, np.uint32).tobytes(),
        np.asarray(secure.nonce_words, np.uint32).tobytes(),
        int(secure.counter0),
        impl, bool(interpret),
        resolve_coalesce(secure.coalesce),
    )


class _CacheView:
    """`run_until(runners=...)` adapter bound to one fully-resolved key base.

    Exposes the driver's duck-typed `get_or_build(n_rounds, build)` —
    `build` (closed over the caller's spec/mesh/secure) is only invoked on a
    miss; the key base already pins everything the closure bakes in.
    Iteration yields the resident chunk sizes for this base, mirroring the
    legacy plain-dict cache (`sorted(view)` works the same way).
    """

    def __init__(self, cache: "RunnerCache", key_base: tuple):
        self.cache = cache
        self.key_base = key_base

    def get_or_build(self, n_rounds: int, build):
        return self.cache.get_or_build(self.key_base + (int(n_rounds),), build)

    def chunk_sizes(self):
        return [k[-1] for k in self.cache.keys() if k[:-1] == self.key_base]

    def __iter__(self):
        return iter(self.chunk_sizes())

    def __len__(self):
        return len(self.chunk_sizes())

    def __contains__(self, n_rounds):
        return self.key_base + (int(n_rounds),) in self.cache.keys()


class RunnerCache:
    """Process-wide keyed LRU cache of compiled `make_iterative_runner`s.

    Keys are (spec identity x mesh x secure material x knobs x chunk size)
    tuples assembled by `view(...)`; values are the driver's runner
    callables (each owning one jitted program). `max_resident` bounds
    residency with least-recently-used eviction; hits / misses / evictions
    are counted, and `compile_cache_size()` sums the resident runners' XLA
    compile-cache entries — the "zero new compiles on a warm resubmit"
    acceptance proof reads this before and after.
    """

    def __init__(self, max_resident="auto"):
        self.max_resident = resolve_max_resident(max_resident)
        self._runners: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def view(self, *, spec_id, mesh: Mesh, axis_name: str,
             secure: SecureShuffleConfig | None = None,
             chacha_impl: str | None = None, loop_impl: str | None = None,
             coalesce=None, donate_state: bool = True) -> _CacheView:
        """Bind a key base; returns the `get_or_build` view `run_until` takes.

        `spec_id` is the caller-chosen workload identity (workload name,
        static shape/knob facts — e.g. ("kmeans", k, d, impl, bucket)); the
        mesh, secure material, and impl knobs are folded in here so callers
        cannot accidentally share a runner across sessions or layouts. The
        view only KEYS on these — building still happens through the
        `build` closure the driver passes to `get_or_build`, which must
        have been constructed from the same arguments (the driver's
        runner-cache contract; `make_kmeans_runner(cache=...)` and
        `SecureJobService` both guarantee this by construction).
        """
        key_base = (
            spec_id,
            _mesh_token(mesh),
            axis_name,
            _secure_token(secure, chacha_impl, coalesce),
            loop_impl or DEFAULT_HALT_LOOP,
            bool(donate_state),
        )
        return _CacheView(self, key_base)

    def get_or_build(self, key, build):
        with self._lock:
            runner = self._runners.get(key)
            if runner is not None:
                self.hits += 1
                self._runners.move_to_end(key)
                return runner
            self.misses += 1
            runner = self._runners[key] = build()
            if self.max_resident is not None:
                while len(self._runners) > self.max_resident:
                    self._runners.popitem(last=False)
                    self.evictions += 1
            return runner

    def keys(self):
        with self._lock:
            return list(self._runners.keys())

    def __len__(self):
        with self._lock:
            return len(self._runners)

    def compile_cache_size(self) -> int:
        """Total XLA compile-cache entries across resident runners."""
        with self._lock:
            runners = list(self._runners.values())
        total = 0
        for runner in runners:
            cache_size = getattr(getattr(runner, "jitted", None),
                                 "_cache_size", None)
            if cache_size is not None:
                total += int(cache_size())
        return total

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "resident": len(self._runners),
                "max_resident": self.max_resident,
                "compile_cache_size": self.compile_cache_size(),
            }

    def clear(self):
        with self._lock:
            self._runners.clear()


_default_cache: RunnerCache | None = None
_default_cache_lock = threading.Lock()


def default_runner_cache() -> RunnerCache:
    """The lazily created process-wide cache (one per process, env-config'd)."""
    global _default_cache
    with _default_cache_lock:
        if _default_cache is None:
            _default_cache = RunnerCache()
        return _default_cache


@dataclass
class JobHandle:
    """Future-backed handle for a submitted job.

    `result(timeout)` blocks for the job's finalized output (a plain dict of
    numpy arrays; see the `submit_*` docstrings). Timing fields are
    `time.perf_counter()` stamps: `latency_s` spans submit -> finish (what a
    client observes), `queue_s` the pre-admission wait. `runner_misses`
    counts the runner-cache misses charged to THIS job — 0 means the job ran
    entirely on cached programs (a warm job).
    """

    job_id: int
    kind: str
    n: int
    bucket: int
    round_base: int
    max_rounds: int
    priority: int = 0
    future: Future = field(default_factory=Future, repr=False)
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    runner_misses: int = 0
    chunks: int = 0

    def result(self, timeout: float | None = None):
        return self.future.result(timeout)

    def done(self) -> bool:
        return self.future.done()

    @property
    def warm(self) -> bool:
        return self.runner_misses == 0

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def queue_s(self) -> float | None:
        if self.started_at is None:
            return None
        return self.started_at - self.submitted_at


class _JobRunners:
    """Per-job wrapper over a `_CacheView` charging cache misses to the job.

    All dispatch happens on the service's single scheduler thread, so the
    before/after miss-counter delta is exactly this job's misses.
    """

    def __init__(self, view: _CacheView, handle: JobHandle):
        self._view = view
        self._handle = handle

    def get_or_build(self, n_rounds, build):
        before = self._view.cache.misses
        runner = self._view.get_or_build(n_rounds, build)
        self._handle.runner_misses += self._view.cache.misses - before
        return runner


class _Job:
    __slots__ = ("handle", "make_gen", "finalize", "gen")

    def __init__(self, handle, make_gen, finalize):
        self.handle = handle
        self.make_gen = make_gen
        self.finalize = finalize
        self.gen = None


class SecureJobService:
    """Serve concurrent secure MapReduce jobs over ONE persistent mesh.

    The service owns its mesh and (optional) `SecureShuffleConfig` for its
    lifetime — the deployment shape of the paper's long-lived enclave
    session. `submit_kmeans` / `submit_sort` / `submit_grep` enqueue a job
    and return a `JobHandle` immediately; a single daemon scheduler thread

      1. ADMITS pending jobs FIFO into up to `max_concurrent` active slots,
      2. round-robins ONE chunk dispatch per active job per pass (the
         driver's cooperative `run_until_chunks` generators — each
         suspended generator owns its carried state and round offset),
      3. resolves the job's future with the finalized host-side result.

    All device dispatch happens on that one thread, so jobs interleave at
    chunk granularity without locking the runtime. Every job is padded up
    to a geometric size bucket (`bucket_for`) and runs on programs from the
    shared `RunnerCache`, so a warm-bucket submit compiles NOTHING; every
    job gets a disjoint global-round range (monotone `round_base` advanced
    by its `max_rounds` budget), so concurrent secure jobs can never reuse
    keystream no matter how their chunks interleave (`core/driver.py`,
    Serving). Jobs submitted in the same order produce bit-identical
    results at any concurrency, including serial.
    """

    def __init__(self, mesh: Mesh, *, axis_name: str = "data",
                 secure: SecureShuffleConfig | None = None,
                 chacha_impl: str | None = None,
                 loop_impl: str | None = None,
                 coalesce: bool | None = None,
                 kmeans_impl: str = "jnp",
                 cache: RunnerCache | None = None,
                 bucket_growth=None,
                 max_concurrent: int = 4,
                 min_chunk: int = 1,
                 max_chunk: int = 8):
        if max_concurrent < 1:
            raise ValueError(f"max_concurrent must be >= 1, got {max_concurrent}")
        if secure is not None:
            # resolve the wire once: the knob tuple the cache keys on is
            # then concrete for the service's whole lifetime
            secure = secure.with_impl(chacha_impl).with_coalesce(coalesce)
            chacha_impl = None
        self.mesh = mesh
        self.axis_name = axis_name
        self.secure = secure
        self.chacha_impl = chacha_impl
        self.loop_impl = loop_impl
        self.coalesce = coalesce
        self.kmeans_impl = kmeans_impl
        self.cache = cache if cache is not None else RunnerCache()
        self.bucket_growth = resolve_bucket_growth(bucket_growth)
        self.max_concurrent = max_concurrent
        self.min_chunk = max(1, min_chunk)
        self.max_chunk = max(self.min_chunk, max_chunk)
        self.n_shards = mesh.shape[axis_name]
        self.state_mode = resolve_state_mode("auto")

        self._cv = threading.Condition()
        # two-level admission queue: priority > 0 jobs admit ahead of the
        # FIFO normal class (FIFO within each class); already-ACTIVE jobs
        # are never preempted — priority orders admission, not dispatch
        self._pending: deque[_Job] = deque()
        self._pending_high: deque[_Job] = deque()
        self._active: list[_Job] = []
        self._next_id = 0
        self._round_base = 0
        self._jobs_completed = 0
        self._closed = False
        self._thread = threading.Thread(
            target=self._scheduler, name="secure-job-service", daemon=True)
        self._thread.start()

    # -- lifecycle ---------------------------------------------------------

    def close(self, wait: bool = True):
        """Stop admitting; drain queued + active jobs, then stop the thread."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def stats(self) -> dict:
        with self._cv:
            return {
                "jobs_completed": self._jobs_completed,
                "jobs_active": len(self._active),
                "jobs_pending": len(self._pending) + len(self._pending_high),
                "round_base": self._round_base,
                "cache": self.cache.stats(),
            }

    # -- scheduler ---------------------------------------------------------

    def _scheduler(self):
        while True:
            with self._cv:
                while (not self._pending and not self._pending_high
                       and not self._active and not self._closed):
                    self._cv.wait()
                if (self._closed and not self._pending
                        and not self._pending_high and not self._active):
                    return
                while ((self._pending or self._pending_high)
                       and len(self._active) < self.max_concurrent):
                    queue = self._pending_high or self._pending
                    self._active.append(queue.popleft())
                batch = list(self._active)
            for job in batch:
                try:
                    if job.gen is None:
                        job.handle.started_at = time.perf_counter()
                        job.gen = job.make_gen(job.handle)
                    next(job.gen)
                    job.handle.chunks += 1
                except StopIteration as stop:
                    self._finish(job, stop.value)
                except BaseException as exc:  # surface through the future
                    self._finish(job, None, exc)

    def _finish(self, job: _Job, res, exc=None):
        if exc is None:
            try:
                value = job.finalize(res)
            except BaseException as finalize_exc:
                exc = finalize_exc
        job.handle.finished_at = time.perf_counter()
        with self._cv:
            self._active.remove(job)
            self._jobs_completed += 1
            self._cv.notify_all()
        if exc is not None:
            job.handle.future.set_exception(exc)
        else:
            job.handle.future.set_result(value)

    def _submit(self, kind, n, bucket, max_rounds, make_gen, finalize,
                priority: int = 0) -> JobHandle:
        priority = int(priority)
        if priority < 0:
            raise ValueError(f"priority must be >= 0, got {priority}")
        with self._cv:
            if self._closed:
                raise RuntimeError("SecureJobService is closed")
            handle = JobHandle(
                job_id=self._next_id, kind=kind, n=n, bucket=bucket,
                round_base=self._round_base, max_rounds=max_rounds,
                priority=priority, submitted_at=time.perf_counter(),
            )
            self._next_id += 1
            # keystream disjointness across jobs: reserve this job's whole
            # round budget on the monotone per-service counter
            self._round_base += max_rounds
            queue = self._pending_high if priority > 0 else self._pending
            queue.append(_Job(handle, make_gen, finalize))
            self._cv.notify()
        return handle

    def _view(self, spec_id) -> _CacheView:
        return self.cache.view(
            spec_id=spec_id, mesh=self.mesh, axis_name=self.axis_name,
            secure=self.secure, chacha_impl=self.chacha_impl,
            loop_impl=self.loop_impl, coalesce=self.coalesce,
        )

    def _run_chunks(self, spec, inputs, init_state, handle, view, *,
                    max_rounds, min_chunk, max_chunk):
        return run_until_chunks(
            spec, inputs, init_state, self.mesh, self.axis_name,
            secure=self.secure, max_rounds=max_rounds,
            round_offset=handle.round_base,
            min_chunk=min_chunk, max_chunk=max_chunk,
            chacha_impl=self.chacha_impl, loop_impl=self.loop_impl,
            coalesce=self.coalesce,
            runners=_JobRunners(view, handle), job_tag=handle.job_id,
        )

    # -- workloads ---------------------------------------------------------

    def submit_kmeans(self, points, k: int, *, threshold: float | None = None,
                      max_rounds: int = 64, weights=None, init_centers=None,
                      min_chunk: int | None = None,
                      max_chunk: int | None = None,
                      priority: int = 0) -> JobHandle:
        """k-means to convergence (paper §V). Result: {"centers" (k, d),
        "n_iter", "shifts" (n_iter,), "halted", "n_dispatches"}.

        The threshold (default: the paper's diag/1000 rule on THIS job's
        data) rides in carried state (`runtime_threshold=True`), so jobs
        with different data share one compiled program per bucket; rows
        padded up to the bucket carry weight 0 and contribute nothing.
        `priority > 0` admits ahead of the normal FIFO class (active jobs
        are never preempted).
        """
        points = np.asarray(points, np.float32)
        if points.ndim != 2 or points.shape[0] < 1:
            raise ValueError(f"points must be (n, d) with n >= 1, got {points.shape}")
        n, d = points.shape
        if not 1 <= k <= n:
            raise ValueError(f"k must be in [1, n={n}], got {k}")
        if weights is None:
            weights = np.ones((n,), np.float32)
        weights = np.asarray(weights, np.float32)
        if init_centers is None:
            init_centers = points[:k]
        init_centers = np.asarray(init_centers, np.float32)
        if threshold is None:
            diag = float(np.linalg.norm(points.max(axis=0) - points.min(axis=0)))
            threshold = diag / 1000.0  # paper §V
        bucket = bucket_for(n, multiple=self.n_shards, growth=self.bucket_growth)
        spec = make_kmeans_iterative_spec(
            k, self.n_shards, impl=self.kmeans_impl, axis_name=self.axis_name,
            runtime_threshold=True)
        view = self._view(("kmeans", k, d, self.kmeans_impl, bucket))
        min_chunk = self.min_chunk if min_chunk is None else min_chunk
        max_chunk = self.max_chunk if max_chunk is None else max_chunk

        def make_gen(handle):
            pts = np.zeros((bucket, d), np.float32)
            pts[:n] = points
            wts = np.zeros((bucket,), np.float32)  # padding weight 0: inert
            wts[:n] = weights
            inputs = {"p": jnp.asarray(pts), "w": jnp.asarray(wts)}
            init = {"c": jnp.asarray(init_centers),
                    "thr": jnp.float32(threshold)}
            return self._run_chunks(spec, inputs, init, handle, view,
                                    max_rounds=max_rounds,
                                    min_chunk=min_chunk, max_chunk=max_chunk)

        def finalize(res):
            return {
                "centers": np.asarray(res.state["c"]),
                "n_iter": res.rounds_executed,
                "shifts": np.asarray(res.aux["shift"]),
                "halted": res.halted,
                "n_dispatches": res.n_dispatches,
            }

        return self._submit("kmeans", n, bucket, max_rounds, make_gen, finalize,
                            priority=priority)

    def submit_sort(self, values, *, balance: float = 1.5, max_rounds: int = 4,
                    lo: float | None = None, hi: float | None = None,
                    capacity: int | None = None,
                    min_chunk: int | None = None,
                    max_chunk: int | None = None,
                    priority: int = 0) -> JobHandle:
        """Sampling sort with splitter refinement. Result: {"sorted" (<= n,),
        "counts" (R,), "rounds", "halted", "dropped" (rounds,)}.

        The record total rides in carried state (`dynamic_total=True`) so
        the lossless+balanced halt reads the REAL size at run time; padding
        up to the bucket is +inf, marked invalid by the map and never
        shuffled. Per-(source, dest) capacity defaults to the bucket's
        lossless worst case.
        """
        values = np.asarray(values, np.float32)
        if values.ndim != 1 or values.shape[0] < 1:
            raise ValueError(f"values must be (n,) with n >= 1, got {values.shape}")
        n = values.shape[0]
        r = self.n_shards
        bucket = bucket_for(n, multiple=r, growth=self.bucket_growth)
        if capacity is None:
            rec = _model_recommendation("sort_capacity", bucket=bucket, n_shards=r)
            capacity = bucket // r if rec is None else int(rec)
        if lo is None:
            lo = float(values.min())
        if hi is None:
            hi = float(values.max())
        span = max(hi - lo, 1e-6)
        spec = make_sample_sort_spec(
            r, capacity, axis_name=self.axis_name, balance=balance,
            shard_state=self.state_mode, dynamic_total=True)
        view = self._view(("sort", r, capacity, float(balance),
                           self.state_mode, bucket))
        min_chunk = self.min_chunk if min_chunk is None else min_chunk
        max_chunk = self.max_chunk if max_chunk is None else max_chunk

        def make_gen(handle):
            vals = np.full((bucket,), np.inf, np.float32)  # +inf: inert pad
            vals[:n] = values
            edges = np.asarray(lo + span * np.arange(r + 1) / r, np.float32)
            edges[-1] = hi + 1e-3 * span  # open top edge keeps hi in-bucket
            init = {
                "edges": jnp.asarray(edges),
                "sorted": jnp.full((r, r * capacity), jnp.inf, jnp.float32),
                "counts": jnp.zeros((r,), jnp.float32),
                "total": jnp.float32(n),
            }
            return self._run_chunks(spec, {"v": jnp.asarray(vals)}, init,
                                    handle, view, max_rounds=max_rounds,
                                    min_chunk=min_chunk, max_chunk=max_chunk)

        def finalize(res):
            rows = np.asarray(res.state["sorted"])
            counts = np.asarray(res.state["counts"])
            out = np.concatenate([rows[i, : int(counts[i])] for i in range(r)])
            return {
                "sorted": out,
                "counts": counts,
                "rounds": res.rounds_executed,
                "halted": res.halted,
                "dropped": np.asarray(res.dropped),
            }

        return self._submit("sort", n, bucket, max_rounds, make_gen, finalize,
                            priority=priority)

    def submit_grep(self, tokens, patterns, *, n_rounds: int = 4,
                    max_matches: int | None = None,
                    min_chunk: int | None = None,
                    max_chunk: int | None = None,
                    priority: int = 0) -> JobHandle:
        """Streaming grep over the token stream. Result: {"counts" (n_pat,),
        "per_round" (rounds, n_pat), "rounds", "halted"}.

        The stream cursor rides in carried state (`core/grep.py`), so the
        job is agnostic to the round base the service assigns it; padding
        up to the bucket is -1 tokens (match no pattern). Without
        `max_matches` the whole stream runs as one fused dispatch; with it,
        chunks grow adaptively so an early limit stops the stream.
        """
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or tokens.shape[0] < 1:
            raise ValueError(f"tokens must be (n,) with n >= 1, got {tokens.shape}")
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        n = tokens.shape[0]
        patterns = np.asarray(patterns, np.int32)
        # bucket aligned to shards x rounds so every shard holds n_rounds
        # equal chunks of the padded stream
        multiple = self.n_shards * n_rounds
        bucket = bucket_for(n, multiple=multiple, growth=self.bucket_growth)
        chunk = bucket // multiple
        spec = make_grep_spec(patterns, chunk, axis_name=self.axis_name,
                              max_matches=max_matches)
        view = self._view(("grep", patterns.tobytes(), chunk,
                           max_matches, bucket))
        if min_chunk is None:
            min_chunk = n_rounds if max_matches is None else 1
        if max_chunk is None:
            max_chunk = n_rounds

        def make_gen(handle):
            toks = np.full((bucket,), -1, np.int32)  # -1: matches no pattern
            toks[:n] = tokens
            init = {"hits": jnp.zeros((patterns.shape[0],), jnp.float32),
                    "cursor": jnp.uint32(0)}
            return self._run_chunks(spec, {"t": jnp.asarray(toks)}, init,
                                    handle, view, max_rounds=n_rounds,
                                    min_chunk=min_chunk, max_chunk=max_chunk)

        def finalize(res):
            return {
                "counts": np.asarray(res.state["hits"]),
                "per_round": np.asarray(res.aux["round_hits"]),
                "rounds": res.rounds_executed,
                "halted": res.halted,
            }

        return self._submit("grep", n, bucket, n_rounds, make_gen, finalize,
                            priority=priority)

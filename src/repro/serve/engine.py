"""Serving: KV/state cache layouts, prefill and one-token decode, per family.

Cache sharding: batch over ('pod','data'); KV heads over 'model' when they
divide the axis, else the cache *sequence* dim is sharded over 'model'
(flash-decode style — XLA turns the softmax reduction into partial sums +
all-reduce). SSM/RWKV states shard their head dim over 'model'.

decode_* / long_* dry-run cells lower `decode_step` with a full-length cache;
`prefill` serves the prefill_32k cells.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import blocks as B
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, compute_dtype, embed_apply, mlp_apply, unembed_apply
from repro.models.lm import _dp, encode_audio
from repro.models.ssm import HEAD_P, ssm_dims


def _kv_head_axis(cfg, mesh):
    if mesh is None or "model" not in mesh.axis_names:
        return None, None
    nm = mesh.shape.get("model", 1)
    if cfg.n_kv_heads % nm == 0 and cfg.n_kv_heads >= nm:
        return "model", None  # shard heads
    return None, "model"  # shard cache sequence


def cache_specs(cfg, mesh, batch: int | None = None):
    """PartitionSpec tree matching init_cache's structure. A batch smaller
    than the dp axis (long-context, batch=1) stays replicated."""
    dp = _dp(mesh)
    if batch is not None and dp is not None and mesh is not None:
        dp_size = 1
        for a in dp if isinstance(dp, tuple) else (dp,):
            dp_size *= mesh.shape[a]
        if batch % dp_size != 0:
            dp = None
    h_ax, s_ax = _kv_head_axis(cfg, mesh)
    kv = P(None, dp, s_ax, h_ax, None)
    pos = P(dp)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"k": kv, "v": kv, "pos": pos}
    if fam == "hybrid":
        return {
            "ssm_h": P(None, dp, "model", None, None),
            "conv": P(None, dp, None, "model"),
            "attn_k": kv,
            "attn_v": kv,
            "pos": pos,
        }
    if fam == "ssm":
        return {
            "tshift": P(None, dp, None, None),
            "wkv": P(None, dp, "model", None, None),
            "cshift": P(None, dp, None, None),
            "pos": pos,
        }
    if fam == "audio":
        # cross-attn cache: encoder frames (e.g. 1500) don't divide the model
        # axis — shard heads when possible, else replicate
        xkv = P(None, dp, None, h_ax, None)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv, "pos": pos}
    raise ValueError(fam)


def init_cache(cfg, batch: int, max_seq: int, mesh=None, dtype=None):
    dt = dtype or compute_dtype(cfg)
    l, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        c = {
            "k": jnp.zeros((l, batch, max_seq, hkv, dh), dt),
            "v": jnp.zeros((l, batch, max_seq, hkv, dh), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    elif fam == "hybrid":
        d_inner, h = ssm_dims(cfg)
        n_inv = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        c = {
            "ssm_h": jnp.zeros((l, batch, h, cfg.ssm_state, HEAD_P), jnp.float32),
            "conv": jnp.zeros((l, batch, cfg.ssm_conv - 1, d_inner), dt),
            "attn_k": jnp.zeros((max(n_inv, 1), batch, max_seq, hkv, dh), dt),
            "attn_v": jnp.zeros((max(n_inv, 1), batch, max_seq, hkv, dh), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    elif fam == "ssm":
        h, dk = rwkv_mod.rwkv_dims(cfg)
        c = {
            "tshift": jnp.zeros((l, batch, 1, cfg.d_model), dt),
            "wkv": jnp.zeros((l, batch, h, dk, dk), jnp.float32),
            "cshift": jnp.zeros((l, batch, 1, cfg.d_model), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    elif fam == "audio":
        senc = cfg.encoder_seq
        c = {
            "k": jnp.zeros((l, batch, max_seq, hkv, dh), dt),
            "v": jnp.zeros((l, batch, max_seq, hkv, dh), dt),
            "xk": jnp.zeros((l, batch, senc, hkv, dh), dt),
            "xv": jnp.zeros((l, batch, senc, hkv, dh), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    else:
        raise ValueError(fam)
    if mesh is not None:
        specs = cache_specs(cfg, mesh)
        c = {
            k: lax.with_sharding_constraint(v, NamedSharding(mesh, specs[k]))
            for k, v in c.items()
        }
    return c


# --- prefill --------------------------------------------------------------------


def _pad_to(x, s, axis=1):
    pad = s - x.shape[axis]
    if pad <= 0:
        return x
    shape = list(x.shape)
    shape[axis] = pad
    return jnp.concatenate([x, jnp.zeros(shape, x.dtype)], axis=axis)


def prefill(cfg, params, tokens, cache, mesh=None, frames=None, secure_moe=None):
    """Fill the cache with `tokens` (B, Tp); returns (last-token logits, cache)."""
    b, t = tokens.shape
    dp = _dp(mesh)
    if mesh is not None and dp is not None:
        dpn = 1
        for a in dp if isinstance(dp, tuple) else (dp,):
            dpn *= mesh.shape[a]
        if b % dpn != 0:
            dp = None

    from repro.models.lm import _seq_ax

    def con(h):
        if mesh is None:
            return h
        seq = _seq_ax(cfg, mesh, h.shape[1]) if h.ndim == 3 else None
        return lax.with_sharding_constraint(
            h, NamedSharding(mesh, P(*((dp, seq) + (None,) * (h.ndim - 2))))
        )

    x = con(embed_apply(cfg, params["embed"], tokens))
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        smax = cache["k"].shape[2]

        def step(carry, inp):
            h = carry
            if fam == "moe":
                p = inp
                hn = apply_norm(cfg, p["ln1"], h)
                a = attn.self_attention(cfg, p["attn"], hn, positions)
                k, v = attn.project_kv(cfg, p["attn"], hn, positions)
                h = h + a
                y, _, _ = moe_mod.moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], h),
                                            mesh=mesh, dp_spec=dp or (), secure=secure_moe)
                h = h + y
            else:
                p = inp
                hn = apply_norm(cfg, p["ln1"], h)
                a = attn.self_attention(cfg, p["attn"], hn, positions)
                k, v = attn.project_kv(cfg, p["attn"], hn, positions)
                h = h + a
                h = h + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], h))
            return con(h), (_pad_to(k, smax), _pad_to(v, smax))

        x, (ks, vs) = lax.scan(step, x, params["layers"])
        cache = dict(cache, k=ks, v=vs, pos=jnp.full((b,), t, jnp.int32))

    elif fam == "ssm":
        def step(h, p):
            h2, (tsh, wkv, csh) = B.apply_rwkv_block(cfg, p, h)
            return con(h2), (tsh, wkv, csh)

        x, (tsh, wkv, csh) = lax.scan(step, x, params["layers"])
        cache = dict(cache, tshift=tsh, wkv=wkv, cshift=csh,
                     pos=jnp.full((b,), t, jnp.int32))

    elif fam == "hybrid":
        smax = cache["attn_k"].shape[2]
        every = cfg.attn_every or (cfg.n_layers + 1)
        hs, convs, aks, avs = [], [], [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            hn = apply_norm(cfg, p["ln1"], x)
            y, (h_end, conv_end) = ssm_mod.ssm_apply(cfg, p["ssm"], hn)
            x = con(x + y)
            hs.append(h_end)
            convs.append(conv_end)
            if (i % every) == (every - 1):
                sp = params["shared_attn"]
                hn = apply_norm(cfg, sp["ln1"], x)
                a = attn.self_attention(cfg, sp["attn"], hn, positions)
                k, v = attn.project_kv(cfg, sp["attn"], hn, positions)
                aks.append(_pad_to(k, smax))
                avs.append(_pad_to(v, smax))
                x = x + a
                x = con(x + mlp_apply(cfg, sp["mlp"], apply_norm(cfg, sp["ln2"], x)))
        cache = dict(
            cache,
            ssm_h=jnp.stack(hs),
            conv=jnp.stack(convs),
            attn_k=jnp.stack(aks) if aks else cache["attn_k"],
            attn_v=jnp.stack(avs) if avs else cache["attn_v"],
            pos=jnp.full((b,), t, jnp.int32),
        )

    elif fam == "audio":
        assert frames is not None, "audio prefill needs frontend frames"
        smax = cache["k"].shape[2]
        enc_kv = encode_audio(cfg, params, frames, mesh)  # (L, ...) k/v

        def step(h, inp):
            p, (xk, xv) = inp
            hn = apply_norm(cfg, p["ln1"], h)
            a = attn.self_attention(cfg, p["attn"], hn, positions)
            k, v = attn.project_kv(cfg, p["attn"], hn, positions)
            h = h + a
            h = h + attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["lnx"], h),
                                         (xk, xv), positions)
            h = h + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], h))
            return con(h), (_pad_to(k, smax), _pad_to(v, smax))

        x, (ks, vs) = lax.scan(step, x, (params["decoder"], enc_kv))
        cache = dict(cache, k=ks, v=vs, xk=enc_kv[0], xv=enc_kv[1],
                     pos=jnp.full((b,), t, jnp.int32))
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params["embed"], x[:, -1:, :])
    return logits[:, 0], cache


# --- decode ---------------------------------------------------------------------


def decode_step(cfg, params, cache, tokens, mesh=None):
    """tokens: (B, 1) — append one token; returns (logits (B, V), cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = embed_apply(cfg, params["embed"], tokens)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def step(h, inp):
            p, ck, cv = inp
            hn = apply_norm(cfg, p["ln1"], h)
            a, nk, nv = attn.decode_self_attention(cfg, p["attn"], hn, ck, cv, pos)
            h = h + a
            if fam == "moe":
                y, _, _ = moe_mod.moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], h),
                                            mesh=mesh, dp_spec=_dp(mesh) or ())
                h = h + y
            else:
                h = h + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], h))
            return h, (nk, nv)

        x, (ks, vs) = lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs, pos=pos + 1)

    elif fam == "ssm":
        def step(h, inp):
            p, tsh, wkv, csh = inp
            y, ntsh, nwkv = rwkv_mod.rwkv_time_mix_step(
                cfg, p["tmix"], apply_norm(cfg, p["ln1"], h), tsh, wkv)
            h = h + y
            hn = apply_norm(cfg, p["ln2"], h)
            y, ncsh = rwkv_mod.rwkv_channel_mix(cfg, p["tmix"], hn, csh)
            return h + y, (ntsh, nwkv, ncsh)

        x, (tsh, wkv, csh) = lax.scan(
            step, x, (params["layers"], cache["tshift"], cache["wkv"], cache["cshift"])
        )
        cache = dict(cache, tshift=tsh, wkv=wkv, cshift=csh, pos=pos + 1)

    elif fam == "hybrid":
        every = cfg.attn_every or (cfg.n_layers + 1)
        hs, convs, aks, avs = [], [], [], []
        inv = 0
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            hn = apply_norm(cfg, p["ln1"], x)
            y, nh, nconv = ssm_mod.ssm_decode_step(cfg, p["ssm"], hn,
                                                   cache["ssm_h"][i], cache["conv"][i])
            x = x + y
            hs.append(nh)
            convs.append(nconv)
            if (i % every) == (every - 1):
                sp = params["shared_attn"]
                hn = apply_norm(cfg, sp["ln1"], x)
                a, nk, nv = attn.decode_self_attention(
                    cfg, sp["attn"], hn, cache["attn_k"][inv], cache["attn_v"][inv], pos)
                aks.append(nk)
                avs.append(nv)
                x = x + a
                x = x + mlp_apply(cfg, sp["mlp"], apply_norm(cfg, sp["ln2"], x))
                inv += 1
        cache = dict(
            cache,
            ssm_h=jnp.stack(hs),
            conv=jnp.stack(convs),
            attn_k=jnp.stack(aks) if aks else cache["attn_k"],
            attn_v=jnp.stack(avs) if avs else cache["attn_v"],
            pos=pos + 1,
        )

    elif fam == "audio":
        def step(h, inp):
            p, ck, cv, xk, xv = inp
            hn = apply_norm(cfg, p["ln1"], h)
            a, nk, nv = attn.decode_self_attention(cfg, p["attn"], hn, ck, cv, pos)
            h = h + a
            h = h + attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["lnx"], h),
                                         (xk, xv), pos[:, None])
            h = h + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], h))
            return h, (nk, nv)

        x, (ks, vs) = lax.scan(
            step, x, (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params["embed"], x)
    return logits[:, 0].astype(jnp.float32), cache

from repro.serve.engine import cache_specs, decode_step, init_cache, prefill

__all__ = ["init_cache", "cache_specs", "prefill", "decode_step"]

from repro.serve.engine import cache_specs, decode_step, init_cache, prefill
from repro.serve.service import (
    JobHandle,
    RunnerCache,
    SecureJobService,
    bucket_for,
    default_runner_cache,
    resolve_bucket_growth,
    resolve_max_resident,
)

__all__ = [
    "init_cache",
    "cache_specs",
    "prefill",
    "decode_step",
    "JobHandle",
    "RunnerCache",
    "SecureJobService",
    "bucket_for",
    "default_runner_cache",
    "resolve_bucket_growth",
    "resolve_max_resident",
]

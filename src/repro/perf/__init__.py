"""Trace-calibrated performance substrate.

`calibrate` runs micro-probes once per (backend, device-count) and persists
them as a calibration JSON; `model` combines a calibration with trace-time
facts (wire bytes + keystream blocks from `core/shuffle.py`'s accounting,
equation counts from `tools/jaxprs.py`) into per-round steady-state,
compile-time, and wire-byte predictions, and answers the `auto` resolvers'
knob questions. With no calibration active every resolver keeps its
historical default bit-for-bit — the model is strictly additive.
"""

from repro.perf.calibrate import (  # noqa: F401
    CALIBRATION_ENV,
    Calibration,
    load_calibration,
    run_calibration,
    save_calibration,
)
from repro.perf.model import (  # noqa: F401
    CostModel,
    active_model,
    clear_active_model,
    recommendation,
    set_active_model,
)

"""Calibrated cost model: predict round time / compile time / wire bytes,
and answer the `auto` resolvers' knob questions.

The byteprofile idiom (ROADMAP): replay a TRACE through per-op costs. Here
the trace is jax's own — `trace_workload` runs `jax.make_jaxpr` over a
runner inside `record_wire_bytes()`, so the wire bytes, collective count,
keystream launches, and ChaCha block count of one round are read off the
traced program (the accounting fires at trace time), and the equation count
comes from `tools/jaxprs.py::total_eqns`. Predictions multiply those counts
by the micro-probed constants in a `Calibration`:

    round_us   = launches·launch_us + eff_blocks·us_per_block      (crypto)
               + collectives·a2a.base_us + wire_bytes·a2a.us_per_byte
               + round.base_us + n_local·round.us_per_item         (compute)
    compile_s  = eqns scaled by the probe program whose equations look most
                 like this one (keystream-bearing programs scale off the
                 chacha probe's compile, plain ones off the round probe's)
    wire_bytes = straight off the trace (already exact)

Knob recommendations (`recommendation(knob)`) are what the `auto` resolvers
in `core/shuffle.py`, `core/driver.py`, and `serve/service.py` consult; the
ACTIVE model comes from `$REPRO_CALIBRATION` (a JSON written by
`perf/calibrate.py`) or an explicit `set_active_model`. No active model →
every recommendation is None → resolvers keep their historical defaults
bit-for-bit.

Known blind spot: workload map/reduce math is priced per ITEM with one
generic slope (the round probe's), so a map_fn doing heavy per-item math is
under-predicted. `benchmarks/bench_costmodel.py`'s pred_error section keeps
this honest against real runs.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.perf.calibrate import (
    CALIBRATION_ENV,
    Calibration,
    effective_blocks,
    load_calibration,
)

_UNSET = object()


@dataclass(frozen=True)
class RoundTrace:
    """Per-round facts read off ONE traced runner program."""

    n_eqns: int
    wire_bytes: int
    collectives: int
    keystream_launches: int
    keystream_blocks: int  # unpadded, summed over launches
    n_shards: int
    n_local_items: int
    secure: bool
    coalesced: bool

    @property
    def blocks_per_launch_row(self) -> int:
        """Unpadded ChaCha blocks per wire row of one launch."""
        if not self.keystream_launches:
            return 0
        return max(1, self.keystream_blocks
                   // (self.keystream_launches * self.n_shards))


def trace_workload(runner, inputs, state, *, n_shards: int,
                   n_local_items: int, round_offset=0) -> RoundTrace:
    """Trace one runner dispatch and distill it into a `RoundTrace`.

    Uses `runner.abstract_fn` (the un-jitted body `make_iterative_runner`
    exposes): the shuffle's trace-time accounting fires during
    `jax.make_jaxpr`, so the wire numbers are the program's own, not an
    estimate. Rounds fused by scan/while trace their shuffle ONCE — exactly
    the per-round quantity the model prices; the masked-scan loop's
    halted-skip branch contributes only `halted` records, which are
    dropped here.
    """
    from repro.core.shuffle import record_wire_bytes
    from repro.tools.jaxprs import total_eqns

    with record_wire_bytes() as recs:
        jaxpr = jax.make_jaxpr(runner.abstract_fn)(
            inputs, state, jnp.uint32(round_offset))
    live = [r for r in recs if not r["halted"]]
    if not live:
        raise ValueError("runner traced no shuffle — nothing to model")
    rec = live[0]
    return RoundTrace(
        n_eqns=total_eqns(jaxpr),
        wire_bytes=int(rec["wire_bytes"]),
        collectives=int(rec["collectives"]),
        keystream_launches=int(rec["keystream_launches"]),
        keystream_blocks=int(rec["keystream_blocks"]),
        n_shards=max(1, int(n_shards)),
        n_local_items=int(n_local_items),
        secure=bool(rec["secure"]),
        coalesced=bool(rec["coalesced"]),
    )


class CostModel:
    """Predictions + knob recommendations over one `Calibration`."""

    def __init__(self, cal: Calibration):
        self.cal = cal
        self._memo: dict = {}

    # -- predictions -------------------------------------------------------

    def _chacha(self, impl: str | None) -> tuple[str, dict]:
        chacha = self.cal.chacha
        if impl is None or impl == "auto":
            impl = self.recommend_chacha_impl()
        entry = chacha.get(impl)
        if entry is None and impl == "pallas-interpret":
            entry = chacha.get("pallas")
        if entry is None:
            entry = next(iter(chacha.values()))
        return impl, entry

    def predict_round_us(self, trace: RoundTrace, impl: str | None = None) -> float:
        """Steady-state microseconds for ONE executed round."""
        cal = self.cal
        us = (cal.round["base_us"]
              + trace.n_local_items * cal.round["us_per_item"]
              + trace.collectives * cal.all_to_all["base_us"]
              + trace.wire_bytes * cal.all_to_all["us_per_byte"])
        if trace.keystream_launches:
            impl, entry = self._chacha(impl)
            kern_impl, interpret = entry.get("resolved", [impl, True])
            eff = trace.keystream_launches * effective_blocks(
                trace.n_shards, trace.blocks_per_launch_row, kern_impl,
                bool(interpret))
            us += (trace.keystream_launches * entry["launch_us"]
                   + eff * entry["us_per_block"])
        return us

    def predict_compile_s(self, trace: RoundTrace, impl: str | None = None) -> float:
        """XLA compile seconds for the runner the trace came from.

        Equation-count scaling anchored on the probe program nearest in
        kind: keystream-bearing traces scale off the chacha probe (its
        equations dominate secure compiles), plain ones off the round
        probe. The plain-XLA s_per_eqn line is the floor.
        """
        cal = self.cal
        floor = cal.compile["base_s"] + trace.n_eqns * cal.compile["s_per_eqn"]
        if trace.keystream_launches:
            _, entry = self._chacha(impl)
            anchor_s, anchor_eqns = entry["compile_s"], entry["compile_eqns"]
        else:
            anchor_s, anchor_eqns = (cal.round["compile_s"],
                                     cal.round["compile_eqns"])
        scaled = anchor_s * trace.n_eqns / max(1, anchor_eqns)
        return max(floor, scaled)

    def predict_wire_bytes(self, trace: RoundTrace) -> int:
        """Wire bytes per round — exact, straight off the trace."""
        return trace.wire_bytes

    def timing_model(self, *, impl: str | None = None,
                     loop_impl: str | None = None, coalesce: bool = True):
        """A `runtime/sim.py::TimingModel` with calibrated constants.

        This is how AdmissionSim's virtual time and the model's predictions
        stay consistent: both read the same probes. Crypto bandwidth comes
        from the chosen impl's us/block (64 bytes each); compile cost is
        the secure-probe compile + the round machinery's.

        The keyword knobs let the offline search (`launch/hillclimb.py`
        cell K) price a WHOLE knob vector: `impl` picks the cipher probe,
        `loop_impl='masked_scan'` doubles compile (both branches trace the
        body, as in `recommend_halt_loop`), and `coalesce=False` pays one
        collective latency per state leaf instead of one total (the same
        nominal tree width `recommend_coalesce` prices).
        """
        from repro.runtime.sim import TimingModel

        cal = self.cal
        _, entry = self._chacha(impl)
        us_blk = max(entry["us_per_block"], 1e-9)
        compile_s = entry["compile_s"] + cal.round["compile_s"]
        if loop_impl == "masked_scan":
            compile_s *= 2.0
        nominal_leaves = 1 if coalesce else 2
        return TimingModel(
            net_latency_s=cal.all_to_all["base_us"] * 1e-6 * nominal_leaves,
            net_bw_bytes_s=1.0 / max(cal.all_to_all["us_per_byte"] * 1e-6, 1e-15),
            enclave_call_s=cal.round["base_us"] * 1e-6,
            crypto_bw_bytes_s=64.0 / (us_blk * 1e-6),
            item_cost_s=cal.round["us_per_item"] * 1e-6,
            xla_compile_s=compile_s,
            dispatch_s=cal.dispatch["base_us"] * 1e-6,
        )

    # -- knob recommendations ---------------------------------------------

    def recommend(self, knob: str, **ctx):
        key = (knob, tuple(sorted(ctx.items())))
        if key not in self._memo:
            self._memo[key] = getattr(self, f"recommend_{knob}")(**ctx)
        return self._memo[key]

    def recommend_chacha_impl(self) -> str:
        """The probed impl with the cheapest nominal launch (256 blocks)."""
        def score(entry):
            return entry["launch_us"] + 256 * entry["us_per_block"]

        return min(self.cal.chacha, key=lambda i: score(self.cal.chacha[i]))

    def recommend_coalesce(self) -> bool:
        """Coalesced iff ONE collective + 2 launches beats per-leaf's
        L + 2L at a nominal tree width — with non-negative probed base
        costs this is always True; the comparison stays, priced, so a
        future negative-overhead backend could flip it."""
        _, entry = self._chacha(None)
        nominal_leaves = 2
        coalesced = self.cal.all_to_all["base_us"] + 2 * entry["launch_us"]
        per_leaf = nominal_leaves * (self.cal.all_to_all["base_us"]
                                     + 2 * entry["launch_us"])
        return coalesced <= per_leaf

    def recommend_halt_loop(self) -> str:
        """'while' vs 'masked_scan': the cond-gated scan traces the round
        body into an extra branch (~2x the equations to compile) and runs
        the masked tail at steady state; 'while' pays neither. Priced via
        the compile predictor so the margin is visible in calibrated terms.
        """
        _, entry = self._chacha(None)
        body_s = entry["compile_s"]
        while_cost = body_s
        masked_cost = 2.0 * body_s  # live + skip branches both trace the body
        return "while" if while_cost <= masked_cost else "masked_scan"

    def recommend_chunk_growth(self, min_chunk: int = 1, max_rounds: int = 64,
                               max_chunk: int | None = None) -> int:
        """Geometric chunk-ladder growth minimizing compile + dispatch cost.

        Each DISTINCT chunk size on the ladder compiles one program (the
        serving RunnerCache regime); each dispatch pays the probed host
        round trip. Steeper growth reaches max_chunk in fewer distinct
        sizes — the compile term, tens of seconds on the secure path,
        dominates the dispatch term, so calibrated backends favor it.
        """
        max_chunk = max_rounds if max_chunk is None else max_chunk
        _, entry = self._chacha(None)
        compile_s = entry["compile_s"] + self.cal.round["compile_s"]
        dispatch_s = self.cal.dispatch["base_us"] * 1e-6

        def cost(growth: int) -> float:
            sizes, dispatches, done = set(), 0, 0
            chunk = max(1, min_chunk)
            while done < max_rounds:
                n = min(chunk, max_rounds - done)
                sizes.add(n)
                dispatches += 1
                done += n
                chunk = min(chunk * growth, max_chunk)
            return len(sizes) * compile_s + dispatches * dispatch_s

        return min((2, 3, 4), key=cost)

    def recommend_bucket_growth(self) -> float:
        """Bucket-ladder growth minimizing AdmissionSim makespan under the
        calibrated TimingModel, summed over the burst + straggler traces
        (the offline knob search `launch/hillclimb.py` runs in full)."""
        from repro.runtime.sim import AdmissionSim, burst_trace, straggler_trace

        timing = self.timing_model()
        traces = [burst_trace(), straggler_trace()]

        def makespan(growth: float) -> float:
            sim = AdmissionSim(timing, bucket_growth=growth)
            return sum(sim.run(t, "bucketed")["makespan_s"] for t in traces)

        return min((1.5, 2.0, 4.0), key=makespan)

    def recommend_max_resident(self):
        """Runner-cache residency cap. Evicting a live program only ever
        adds recompiles (the sim charges nothing for residency), so the
        predicted optimum is unbounded — returned as the string
        'unbounded' so callers can tell "model says no cap" from "no
        model"."""
        return "unbounded"

    def recommend_capacity_factor(self) -> float:
        """Auto-capacity headroom factor (ceil(n/R) * factor).

        Overflow is KEY-DISTRIBUTION-dependent — no backend probe can bound
        another workload's skew, and an undershot capacity silently drops
        records. The model therefore only recommends a non-default factor
        when the calibration carries a deployment-measured
        `extra["capacity_factor"]`; otherwise it prices the conservative
        historical 2.0.
        """
        return float(self.cal.extra.get("capacity_factor", 2.0))

    def recommend_sort_capacity(self, bucket: int, n_shards: int) -> int:
        """Per-(source, dest) sort capacity: smallest wire that stays
        LOSSLESS. Absent measured key skew in the calibration, the binding
        constraint is the worst case (one splitter range owns a source's
        whole slice), so the lossless minimum is bucket // n_shards —
        candidates below it can drop records, which no wire saving buys
        back."""
        return max(1, bucket // max(1, n_shards))


# -- active-model plumbing ---------------------------------------------------

_active: object = _UNSET  # explicit override: a CostModel, or None = forced off
_env_cache: tuple | None = None  # (path, mtime, CostModel | None)


def set_active_model(model: CostModel | None) -> None:
    """Explicitly set (or with None, force OFF) the active model.

    Wins over $REPRO_CALIBRATION until `clear_active_model`. Test and
    benchmark hook — production activation is the env var.
    """
    global _active
    _active = model


def clear_active_model() -> None:
    """Drop any explicit override AND the env-file cache."""
    global _active, _env_cache
    _active = _UNSET
    _env_cache = None


def active_model() -> CostModel | None:
    """The model the `auto` resolvers consult, or None (= use defaults).

    Resolution order: explicit `set_active_model` value, else the
    calibration JSON named by $REPRO_CALIBRATION (entry matching this
    process's backend/device-count; cached by file mtime), else None. An
    unreadable file or missing entry resolves to None — the strictly-
    additive contract: a bad calibration can cost performance, never
    correctness or a crash at resolve time.
    """
    global _env_cache
    if _active is not _UNSET:
        return _active  # type: ignore[return-value]
    path = os.environ.get(CALIBRATION_ENV)
    if not path:
        return None
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return None
    if _env_cache and _env_cache[0] == path and _env_cache[1] == mtime:
        return _env_cache[2]
    try:
        cal = load_calibration(path)
        model = None if cal is None else CostModel(cal)
    except Exception:
        model = None
    _env_cache = (path, mtime, model)
    return model


def recommendation(knob: str, **ctx):
    """`active_model().recommend(knob)`, or None when no model is active."""
    model = active_model()
    if model is None:
        return None
    return model.recommend(knob, **ctx)

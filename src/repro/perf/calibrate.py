"""Micro-probe calibration: measure the constants the cost model multiplies.

One calibration is a handful of fitted (slope, intercept) lines, probed ONCE
per (backend, device-count) and persisted to JSON:

  chacha[impl]   us per ChaCha20 block + us per launch, fitted over several
                 wire widths as the secure-minus-plaintext difference of a
                 REAL fused driver round (a standalone kernel call can't
                 see that a round's encrypt and decrypt launches share one
                 CSE'd keystream derivation), plus the secure probe
                 program's compile seconds and jaxpr equation count
                 (the compile-time predictor's scaling anchor);
  all_to_all     us per wire byte + us per collective, through a shard_map
                 `lax.all_to_all` on this process's actual mesh;
  dispatch       us per jitted host->device round trip (trivial program);
  round          us per mapped item + us of fixed per-round machinery,
                 fitted over input sizes through a minimal PLAINTEXT
                 iterative-driver round (bucket_pack + all_to_all + reduce
                 — the real scan body, so the intercept prices the real
                 scan/shard_map overhead), plus its compile stats;
  compile        seconds per jaxpr equation + base, from two plain XLA
                 programs of different sizes (the floor for programs with
                 no keystream in them).

Activation is EXPLICIT: `$REPRO_CALIBRATION=<path>` (or
`repro.perf.model.set_active_model`). Nothing is read implicitly from the
working directory, so with the variable unset every `auto` resolver keeps
its historical default bit-for-bit.

CLI:  PYTHONPATH=src python -m repro.perf.calibrate --out calibration.json
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import asdict, dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

CALIBRATION_ENV = "REPRO_CALIBRATION"
SCHEMA = 1

# payload widths (f32 words per item) for the chacha fit: wire block counts
# span ~10x so both the slope and the intercept are anchored
_CHACHA_WIDTHS = (1, 8, 32)
_CHACHA_WIDTHS_QUICK = (1, 16)
_A2A_WORDS = (1 << 10, 1 << 14)
_ROUND_SIZES = (256, 1024, 4096)


@dataclass(frozen=True)
class Calibration:
    """Fitted probe constants for one (backend, device-count) pair.

    All times are microseconds unless the field name says seconds. `extra`
    carries optional deployment-measured overrides the model consults but
    never probes itself (e.g. "capacity_factor" for a measured key skew).
    """

    backend: str
    n_devices: int
    chacha: dict  # impl -> {us_per_block, launch_us, compile_s, compile_eqns}
    all_to_all: dict  # {us_per_byte, base_us}
    dispatch: dict  # {base_us}
    round: dict  # {us_per_item, base_us, compile_s, compile_eqns}
    compile: dict  # {s_per_eqn, base_s}
    schema: int = SCHEMA
    extra: dict = field(default_factory=dict)

    @property
    def key(self) -> str:
        return f"{self.backend}/{self.n_devices}"

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        return cls(**{k: d[k] for k in cls.__dataclass_fields__ if k in d})


# -- probe plumbing ----------------------------------------------------------


def _time_us(fn, *args, reps: int = 7) -> float:
    """Best steady-state wall time of `fn(*args)` in us (post-warmup).

    Min over reps, the microbenchmark standard: every source of jitter on
    a shared box (scheduler, thermal, GC) only ever ADDS time, so the
    minimum is the least-contaminated estimate of the program's cost —
    and the quantity the bench's interleaved measurement reproduces.
    """
    return _interleaved_best_us([(fn, args)], reps=reps)[0]


def _interleaved_best_us(entries, reps: int = 7) -> list:
    """Best wall time (us) per (fn, args) entry, trials INTERLEAVED.

    A probe that fits a line across program sizes must time every size
    under the SAME machine conditions — compiling the next size's program
    between timing phases (tens of seconds for the secure probes) lets
    load drift corrupt the slope. All entries are warmed first, then
    trials round-robin across them.
    """
    for fn, args in entries:
        jax.block_until_ready(fn(*args))
    best = [float("inf")] * len(entries)
    for _ in range(max(1, reps)):
        for i, (fn, args) in enumerate(entries):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], (time.perf_counter() - t0) * 1e6)
    return best


def _compile_s(jitted, *args) -> float:
    """Seconds to XLA-compile `jitted(*args)` (lowering excluded)."""
    lowered = jitted.lower(*args)
    t0 = time.perf_counter()
    lowered.compile()
    return time.perf_counter() - t0


def _fit_line(xs, ys) -> tuple[float, float]:
    """Least-squares y = slope*x + intercept, both clamped >= 0."""
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    if len(xs) < 2 or np.ptp(xs) == 0:
        return 0.0, float(ys.mean())
    slope, intercept = np.polyfit(xs, ys, 1)
    return max(float(slope), 0.0), max(float(intercept), 0.0)


def effective_blocks(rows: int, blocks_per_row: int, impl: str,
                     interpret: bool) -> int:
    """ChaCha block-equivalents a launch actually pays for.

    Mirrors `kernels/chacha20/ops.py::_lane_tile`: interpret mode pads each
    row's block count up to an 8-multiple (min 8) so the emulator runs one
    tile; compiled Pallas pads to full 128-lane VREG multiples; the jnp
    oracle derives exactly the blocks the wire needs. The calibration fit
    and the model's predictor both price THIS quantity, so kernel padding
    is never mistaken for payload work.

    Interpret mode additionally multiplies by `rows`: the emulator's grid
    loop rewrites the FULL (rows, words) buffer once per grid row
    (dynamic_update_slice of the whole output), so its measured cost grows
    as rows^2 x padded blocks — one fitted slope then lands within ~15% on
    1-row and 8-row launches alike, where a linear-in-blocks fit is off by
    ~8x on whichever regime it wasn't anchored to.
    """
    if blocks_per_row == 0 or rows == 0:
        return 0
    if impl == "jnp":
        return rows * blocks_per_row
    if interpret:
        return rows * rows * max(8, -(-blocks_per_row // 8) * 8)
    return rows * max(128, -(-blocks_per_row // 128) * 128)


# -- probes ------------------------------------------------------------------


def _probe_chacha(impl: str, mesh, axis_name: str, widths) -> dict:
    """Crypto cost measured through the REAL secure driver round.

    Times the minimal driver round (`_probe_round`'s spec, payload widened
    to `d` f32 words per item) secure vs PLAINTEXT at each width; the
    difference is exactly what the keystream path adds to one fused round.
    A standalone `chacha20_xor_rows` microbenchmark cannot measure this:
    its per-call host dispatch lands in the intercept, and — decisive on
    the secure path — the fused round's encrypt and decrypt launches
    derive the SAME keystream by construction (that is what decryption
    means for a stream cipher), so XLA CSEs the derivation and a real
    round pays for it once. The fitted intercept is split per launch so
    `predict_round_us`'s launches x launch_us term scales to per-leaf
    wires; the per-dispatch overhead cancels in the secure-minus-plain
    difference.
    """
    from repro.core.driver import IterativeSpec, make_iterative_runner
    from repro.core.shuffle import (
        SecureShuffleConfig,
        record_wire_bytes,
        resolve_chacha_impl,
    )
    from repro.crypto import chacha as chacha_mod
    from repro.tools.jaxprs import total_eqns

    kern_impl, interpret = resolve_chacha_impl(impl)
    r_sh = mesh.shape[axis_name]
    n = -(-256 // r_sh) * r_sh
    n_rounds = 4
    sec = SecureShuffleConfig(
        key_words=chacha_mod.key_to_words(bytes(range(32))),
        nonce_words=chacha_mod.nonce_to_words(b"\x07" * 12),
        impl=impl)

    def map_fn(state, inputs, r):
        keys = jnp.arange(inputs["x"].shape[0], dtype=jnp.int32) % 8
        return keys, {"x": inputs["x"]}

    def reduce_fn(state, keys, values, valid, r):
        s = jnp.sum(jnp.where(valid[:, None], values["x"], 0.0))
        return {"s": state["s"] + lax.psum(s, axis_name)}, {"s": s}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, n_rounds=n_rounds)
    # a dedicated tiny-wire anchor leads the sweep: workloads that shuffle
    # AGGREGATES (k-means moves k cluster sums, not n points) ride ~6-block
    # wires, and the crypto cost curve is concave near zero — a fit whose
    # nearest anchor is ~40 blocks extrapolates a badly inflated intercept
    # down into that regime
    anchors = [(-(-16 // r_sh) * r_sh, widths[0])] + [(n, d) for d in widths]
    xs, entries = [], []
    compile_s = compile_eqns = None
    for n_d, d in anchors:
        inputs = {"x": jnp.ones((n_d, d), jnp.float32)}
        state = {"s": jnp.float32(0)}
        secure_runner = make_iterative_runner(spec, mesh, axis_name, secure=sec)
        plain_runner = make_iterative_runner(spec, mesh, axis_name)
        with record_wire_bytes() as recs:
            jaxpr = jax.make_jaxpr(secure_runner.abstract_fn)(
                inputs, state, jnp.uint32(0))
        (rec,) = [r for r in recs if r["secure"] and not r["halted"]]
        launches = max(1, rec["keystream_launches"])
        bpr = max(1, rec["keystream_blocks"] // (launches * r_sh))
        xs.append(launches * effective_blocks(r_sh, bpr, kern_impl, interpret))
        entries.append((secure_runner, (inputs, state)))
        entries.append((plain_runner, (inputs, state)))
        if compile_s is None:
            compile_s = _compile_s(secure_runner.jitted, inputs, state,
                                   jnp.uint32(0))
            compile_eqns = total_eqns(jaxpr)
    timed = _interleaved_best_us(entries)
    ys = [max(0.0, (timed[2 * i] - timed[2 * i + 1]) / n_rounds)
          for i in range(len(anchors))]
    slope, intercept = _fit_line(xs, ys)
    return {"us_per_block": slope, "launch_us": intercept / 2.0,
            "compile_s": float(compile_s), "compile_eqns": int(compile_eqns),
            "resolved": [kern_impl, bool(interpret)]}


def _probe_all_to_all(mesh, axis_name: str, sizes) -> dict:
    from repro import compat

    r = mesh.shape[axis_name]

    def body(x):
        return lax.all_to_all(x, axis_name, 0, 0, tiled=True)

    xs, ys = [], []
    for words in sizes:
        fn = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
            check_vma=False))
        x = jnp.zeros((r * r, max(1, words // r)), jnp.uint32)
        xs.append(x.size // r * 4)  # bytes leaving ONE device's shard
        ys.append(_time_us(fn, x))
    slope, intercept = _fit_line(xs, ys)
    return {"us_per_byte": slope, "base_us": intercept}


def _probe_dispatch() -> dict:
    fn = jax.jit(lambda x: x + 1)
    return {"base_us": _time_us(fn, jnp.zeros((8,), jnp.float32))}


def _probe_round(mesh, axis_name: str, sizes) -> dict:
    """A minimal PLAINTEXT driver round: the real scan/shuffle machinery.

    The intercept prices everything a round pays regardless of payload
    (shard_map + scan step + bucket_pack bookkeeping + the collective's
    base cost at its calibrated size); the slope prices per-mapped-item
    work. Workload map/reduce math rides on the slope — generic, so a
    heavy map_fn is the model's known blind spot (documented there).
    """
    from repro.core.driver import IterativeSpec, make_iterative_runner
    from repro.tools.jaxprs import total_eqns

    n_rounds = 4

    def map_fn(state, inputs, r):
        x = inputs["x"]
        keys = jnp.arange(x.shape[0], dtype=jnp.int32) % 8
        return keys, {"x": x}

    def reduce_fn(state, keys, values, valid, r):
        s = jnp.sum(jnp.where(valid, values["x"], 0.0))
        return {"s": state["s"] + lax.psum(s, axis_name)}, {"s": s}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, n_rounds=n_rounds)
    r_sh = mesh.shape[axis_name]
    xs, entries = [], []
    compile_s = compile_eqns = None
    for n in sizes:
        n = -(-n // r_sh) * r_sh
        runner = make_iterative_runner(spec, mesh, axis_name)
        inputs = {"x": jnp.ones((n,), jnp.float32)}
        state = {"s": jnp.float32(0)}
        xs.append(n // r_sh)  # per-shard mapped items, what round_delay sees
        entries.append((runner, (inputs, state)))
        if compile_s is None:
            compile_s = _compile_s(runner.jitted, inputs, state, jnp.uint32(0))
            compile_eqns = total_eqns(
                jax.make_jaxpr(runner.abstract_fn)(inputs, state, jnp.uint32(0)))
    ys = [us / n_rounds for us in _interleaved_best_us(entries)]
    slope, intercept = _fit_line(xs, ys)
    return {"us_per_item": slope, "base_us": intercept,
            "compile_s": float(compile_s), "compile_eqns": int(compile_eqns)}


def _probe_compile() -> dict:
    from repro.tools.jaxprs import total_eqns

    def chain(n):
        def f(x):
            for i in range(n):
                x = jnp.sin(x) + np.float32(i)
            return x
        return f

    xs, ys = [], []
    for n in (16, 160):
        f = chain(n)
        x = jnp.ones((128,), jnp.float32)
        xs.append(total_eqns(jax.make_jaxpr(f)(x)))
        ys.append(_compile_s(jax.jit(f), x))
    slope, intercept = _fit_line(xs, ys)
    return {"s_per_eqn": slope, "base_s": intercept}


# -- entry points ------------------------------------------------------------


def run_calibration(mesh=None, *, axis_name: str = "data",
                    impls=("pallas", "jnp"), quick: bool = False) -> Calibration:
    """Run every probe on this process's backend; return the Calibration.

    `mesh` defaults to a 1-axis mesh over every local device (the shape the
    collective probe and the device-count key describe). `quick` trims the
    fit widths — the CI autotune lane's mode.
    """
    from repro.compat import make_mesh

    if mesh is None:
        n_dev = jax.device_count()
        mesh = make_mesh((n_dev,), (axis_name,))
    widths = _CHACHA_WIDTHS_QUICK if quick else _CHACHA_WIDTHS
    round_sizes = _ROUND_SIZES
    return Calibration(
        backend=jax.default_backend(),
        n_devices=jax.device_count(),
        chacha={impl: _probe_chacha(impl, mesh, axis_name, widths)
                for impl in impls},
        all_to_all=_probe_all_to_all(mesh, axis_name, _A2A_WORDS),
        dispatch=_probe_dispatch(),
        round=_probe_round(mesh, axis_name, round_sizes),
        compile=_probe_compile(),
    )


def save_calibration(cal: Calibration, path: str) -> None:
    """Merge `cal` into the JSON at `path`, keyed by backend/device-count."""
    doc = {"schema": SCHEMA, "calibrations": {}}
    try:
        with open(path) as f:
            loaded = json.load(f)
        if isinstance(loaded.get("calibrations"), dict):
            doc = loaded
    except (OSError, ValueError):
        pass
    doc["calibrations"][cal.key] = cal.to_dict()
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)


def load_calibration(path: str, *, backend: str | None = None,
                     n_devices: int | None = None) -> Calibration | None:
    """Load the entry matching (backend, n_devices); None when absent.

    Defaults to THIS process's backend and device count — a calibration
    probed on a different shape says nothing about this one, so a missing
    key falls back to no model (and therefore to the historical defaults)
    rather than to a wrong one.
    """
    backend = backend if backend is not None else jax.default_backend()
    n_devices = n_devices if n_devices is not None else jax.device_count()
    with open(path) as f:
        doc = json.load(f)
    entry = doc.get("calibrations", {}).get(f"{backend}/{n_devices}")
    return None if entry is None else Calibration.from_dict(entry)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="calibration.json")
    ap.add_argument("--impls", default="pallas,jnp",
                    help="comma-separated chacha impls to probe")
    ap.add_argument("--quick", action="store_true",
                    help="fewer fit points (CI autotune lane)")
    args = ap.parse_args(argv)
    cal = run_calibration(impls=tuple(args.impls.split(",")), quick=args.quick)
    save_calibration(cal, args.out)
    print(f"calibrated {cal.key}: "
          + ", ".join(f"{i}={c['us_per_block']:.3f}us/blk+{c['launch_us']:.0f}us"
                      for i, c in cal.chacha.items())
          + f"; a2a {cal.all_to_all['us_per_byte']*1e3:.3f}ns/B"
          + f"; round {cal.round['base_us']:.0f}us"
          + f" -> {args.out}")


if __name__ == "__main__":
    main()

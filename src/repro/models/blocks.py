"""Block composition per architecture family (pre-norm residual blocks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import _key, apply_norm, mlp_apply, mlp_axes, mlp_init, norm_axes, norm_init


def block_init(key, cfg, kind: str, n_model: int = 1):
    d = cfg.d_model
    if kind in ("attn", "enc"):
        return {
            "ln1": norm_init(key, d),
            "attn": attn.attn_init(_key(key, "attn"), cfg),
            "ln2": norm_init(key, d),
            "mlp": mlp_init(_key(key, "mlp"), d, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln1": norm_init(key, d),
            "attn": attn.attn_init(_key(key, "attn"), cfg),
            "ln2": norm_init(key, d),
            "moe": moe_mod.moe_init(_key(key, "moe"), cfg, n_model),
        }
    if kind == "mamba":
        return {"ln1": norm_init(key, d), "ssm": ssm_mod.ssm_init(_key(key, "ssm"), cfg)}
    if kind == "rwkv":
        return {
            "ln1": norm_init(key, d),
            "tmix": rwkv_mod.rwkv_init(_key(key, "tmix"), cfg),
            "ln2": norm_init(key, d),
        }
    if kind == "dec_cross":
        return {
            "ln1": norm_init(key, d),
            "attn": attn.attn_init(_key(key, "attn"), cfg),
            "lnx": norm_init(key, d),
            "xattn": attn.attn_init(_key(key, "xattn"), cfg, cross=True),
            "ln2": norm_init(key, d),
            "mlp": mlp_init(_key(key, "mlp"), d, cfg.d_ff),
        }
    raise ValueError(kind)


def block_axes(cfg, kind: str):
    d = cfg.d_model
    if kind in ("attn", "enc"):
        return {"ln1": norm_axes(d), "attn": attn.attn_axes(cfg), "ln2": norm_axes(d),
                "mlp": mlp_axes()}
    if kind == "moe":
        return {"ln1": norm_axes(d), "attn": attn.attn_axes(cfg), "ln2": norm_axes(d),
                "moe": moe_mod.moe_axes(cfg)}
    if kind == "mamba":
        return {"ln1": norm_axes(d), "ssm": ssm_mod.ssm_axes(cfg)}
    if kind == "rwkv":
        return {"ln1": norm_axes(d), "tmix": rwkv_mod.rwkv_axes(cfg), "ln2": norm_axes(d)}
    if kind == "dec_cross":
        return {"ln1": norm_axes(d), "attn": attn.attn_axes(cfg), "lnx": norm_axes(d),
                "xattn": attn.attn_axes(cfg), "ln2": norm_axes(d), "mlp": mlp_axes()}
    raise ValueError(kind)


def remat_wrap(cfg, fn, names=()):
    """Remat policy. `names` whitelists checkpoint_name'd intermediates (e.g.
    the MoE all_to_all results) so backward does NOT replay collectives."""
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if names:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(*names)
        )
    return jax.checkpoint(fn)


# --- training / prefill (no cache) apply --------------------------------------


def apply_attn_block(cfg, p, x, positions, causal=None):
    h = attn.self_attention(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions,
                            causal=causal)
    x = x + h
    x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x


def apply_moe_block(cfg, p, x, positions, mesh=None, dp_spec=("pod", "data"), secure=None):
    h = attn.self_attention(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions)
    x = x + h
    y, aux, dropped = moe_mod.moe_apply(
        cfg, p["moe"], apply_norm(cfg, p["ln2"], x), mesh=mesh, dp_spec=dp_spec,
        secure=secure,
    )
    return x + y, aux, dropped


def apply_mamba_block(cfg, p, x, h0=None, conv0=None):
    y, (h_end, conv_end) = ssm_mod.ssm_apply(cfg, p["ssm"], apply_norm(cfg, p["ln1"], x),
                                             h0, conv0)
    return x + y, h_end, conv_end


def apply_rwkv_block(cfg, p, x, states=None):
    # p["tmix"] holds both time-mix and channel-mix (cm_*) parameters.
    s = states or (None, None, None)  # (tmix shift, wkv, cmix shift)
    y, (tshift, wkv) = rwkv_mod.rwkv_time_mix(cfg, p["tmix"], apply_norm(cfg, p["ln1"], x),
                                              s[0], s[1])
    x = x + y
    y, cshift = rwkv_mod.rwkv_channel_mix(cfg, p["tmix"], apply_norm(cfg, p["ln2"], x), s[2])
    return x + y, (tshift, wkv, cshift)


def apply_dec_cross_block(cfg, p, x, positions, enc_kv, enc_valid=None):
    h = attn.self_attention(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions)
    x = x + h
    h = attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["lnx"], x), enc_kv,
                             positions, enc_valid)
    x = x + h
    x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x

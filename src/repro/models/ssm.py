"""Mamba2 / SSD block (zamba2's backbone), chunked-parallel form.

Recurrence per head (state h: (N, P), scalar decay per head/step):
    h_t = a_t h_{t-1} + dt_t · B_t ⊗ x_t          a_t = exp(-dt_t·exp(A_log))
    y_t = C_t · h_t + D ⊙ x_t
Chunked evaluation (Mamba-2 SSD): within a chunk of Q steps the causal decay
matrix L_ij = exp(La_i − La_j) (i ≥ j, La = cumsum log a) is formed directly
— differences are ≤ 0, so no overflow — giving an O(Q²) intra-chunk term plus
an O(N·P) carried state between chunks. Backward memory is O(T/Q) states
instead of O(T).

Simplifications vs the full Mamba2 block (documented in DESIGN.md): the
short causal conv is applied to x only (not B/C); single B/C group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import _key, ninit

HEAD_P = 64  # per-head channels (Mamba2 default headdim)


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // HEAD_P
    return d_inner, n_heads


def ssm_init(key, cfg):
    d = cfg.d_model
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    w = cfg.ssm_conv
    return {
        # projections: x, z (gate), B, C, dt
        "in_proj": ninit(_key(key, "in"), (d, 2 * d_inner + 2 * n + h)),
        "conv_w": jax.random.normal(_key(key, "conv"), (w, d_inner)) * 0.2,
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": ninit(_key(key, "out"), (d_inner, d), fan_in=d_inner),
    }


def ssm_axes(cfg):
    return {
        "in_proj": ("fsdp", "mlp"),
        "conv_w": ("dconv", "mlp"),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "out_proj": ("mlp", "fsdp"),
    }


def _split_proj(cfg, proj):
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    xz, rest = proj[..., : 2 * d_inner], proj[..., 2 * d_inner :]
    x, z = xz[..., :d_inner], xz[..., d_inner:]
    bm = rest[..., :n]
    cm = rest[..., n : 2 * n]
    dt = rest[..., 2 * n :]
    return x, z, bm, cm, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv; x (B,T,D), w (W,D). state: (B,W-1,D) or None."""
    wlen = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(wlen))
    new_state = xp[:, -(wlen - 1) :] if wlen > 1 else pad
    return jax.nn.silu(y), new_state


def ssd_chunked(xh, dt, a_log, bm, cm, h0, chunk: int):
    """Chunked SSD scan.

    xh: (B,T,H,P)  dt: (B,T,H)  bm/cm: (B,T,N)  h0: (B,H,N,P)
    Returns y (B,T,H,P), h_end (B,H,N,P).
    """
    b, t, h, p = xh.shape
    n = bm.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q

    loga = -dt * jnp.exp(a_log.astype(jnp.float32))[None, None, :]  # (B,T,H) <= 0
    xs = (
        xh.reshape(b, nc, q, h, p),
        dt.reshape(b, nc, q, h),
        loga.reshape(b, nc, q, h),
        bm.reshape(b, nc, q, n),
        cm.reshape(b, nc, q, n),
    )
    xs = jax.tree.map(lambda v: jnp.moveaxis(v, 1, 0), xs)  # lead chunk dim

    def body(hc, inp):
        xq, dtq, lq, bq, cq = inp  # (B,Q,...)
        la = jnp.cumsum(lq, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: y_i += sum_{j<=i} exp(la_i - la_j) (C_i·B_j) dt_j x_j
        decay = la[:, :, None, :] - la[:, None, :, :]  # (B,Q,Q,H) i,j
        mask = jnp.tril(jnp.ones((q, q), bool))
        ldec = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32), bq.astype(jnp.float32))
        gate = cb[:, :, :, None] * ldec  # (B,Q,Q,H)
        xdt = xq.astype(jnp.float32) * dtq[..., None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", gate, xdt)
        # inter-chunk: y_i += exp(la_i) C_i · h_in
        y_inter = jnp.einsum("bin,bhnp->bihp", cq.astype(jnp.float32), hc) * jnp.exp(
            la
        )[..., None]
        # state: h_out = exp(la_Q) h_in + sum_j exp(la_Q - la_j) dt_j B_j (x) x_j
        tail = jnp.exp(la[:, -1:, :] - la)  # (B,Q,H)
        hb = jnp.einsum("bjn,bjhp->bhnp", bq.astype(jnp.float32), xdt * tail[..., None])
        h_out = hc * jnp.exp(la[:, -1])[:, :, None, None] + hb
        return h_out, (y_intra + y_inter).astype(xq.dtype)

    h_end, ys = lax.scan(body, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, p)
    return y, h_end


def ssm_apply(cfg, params, x, h0=None, conv_state=None, chunk: int = 256):
    """Full-sequence SSM block. Returns (y, (h_end, conv_end))."""
    b, t, d = x.shape
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    proj = jnp.einsum("btd,dk->btk", x, params["in_proj"].astype(x.dtype))
    xc, z, bm, cm, dt = _split_proj(cfg, proj)
    xc, conv_end = _causal_conv(xc, params["conv_w"], conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    xh = xc.reshape(b, t, h, HEAD_P)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, HEAD_P), jnp.float32)
    # pick a chunk that divides T
    q = chunk
    while t % q != 0:
        q //= 2
    y, h_end = ssd_chunked(xh, dt, params["a_log"], bm, cm, h0, q)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, t, d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("btk,kd->btd", y, params["out_proj"].astype(x.dtype))
    return out, (h_end, conv_end)


def ssm_decode_step(cfg, params, x, h_state, conv_state):
    """One-token step. x: (B,1,d); h_state (B,H,N,P); conv (B,W-1,d_inner)."""
    b, _, d = x.shape
    d_inner, h = ssm_dims(cfg)
    proj = jnp.einsum("btd,dk->btk", x, params["in_proj"].astype(x.dtype))
    xc, z, bm, cm, dt = _split_proj(cfg, proj)
    xc, conv_new = _causal_conv(xc, params["conv_w"], conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    xh = xc.reshape(b, h, HEAD_P).astype(jnp.float32)
    a = jnp.exp(-dt * jnp.exp(params["a_log"])[None, :])  # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", bm[:, 0].astype(jnp.float32), xh * dt[..., None])
    h_new = h_state * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(jnp.float32), h_new)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("btk,kd->btd", y, params["out_proj"].astype(x.dtype))
    return out, h_new, conv_new

"""Model zoo: the 10 assigned architectures as one composable LM stack.

Families: dense GQA decoders (mistral-large/deepseek/glm4/granite-20b,
chameleon-vlm), MoE decoders with secure-shuffle expert dispatch
(granite-moe, qwen2-moe), encoder-decoder (whisper), hybrid Mamba2+shared-attn
(zamba2), attention-free RWKV6 (rwkv6).

Everything is functional: `init_params(cfg, key)` -> pytree,
`param_axes(cfg)` -> logical-axes pytree (same structure), and pure apply
functions. Layer stacks are `lax.scan`-over-layers so HLO size is O(1) in
depth (512-way SPMD compiles stay tractable).
"""

from repro.models.lm import init_params, param_axes, loss_fn, forward

__all__ = ["init_params", "param_axes", "loss_fn", "forward"]

"""RWKV-6 "Finch" block: data-dependent-decay linear attention, attention-free.

Time-mix core (per head, state S: (Dk, Dv)):
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    y_t = r_t S_{t-1} + (r_t ⊙ u · k_t) v_t
with w_t = exp(-exp(ww_t)) a *data-dependent* per-channel decay (the Finch
contribution) produced by a low-rank MLP on the token-shift mix; u is the
bonus for the current token. Channel-mix is the squared-ReLU variant.

Training runs a `lax.scan` over time wrapped in per-chunk `jax.checkpoint`
(sequential but numerically exact; the GLA-style parallel form needs
exp(+cumsum) factors that overflow fp32 for strong decays — see DESIGN.md).
Simplified vs upstream: static token-shift mix coefficients (no ddlerp LoRA
on the mix), GroupNorm on y replaced by per-head RMS normalization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import _key, ninit

HEAD_K = 64  # per-head key/value channels
DECAY_RANK = 32


def rwkv_dims(cfg):
    h = cfg.d_model // HEAD_K
    return h, HEAD_K


def rwkv_init(key, cfg):
    d = cfg.d_model
    h, dk = rwkv_dims(cfg)
    return {
        "mix": jax.random.uniform(_key(key, "mix"), (5, d)),  # r,k,v,w,g shift mixes
        "wr": ninit(_key(key, "wr"), (d, d)),
        "wk": ninit(_key(key, "wk"), (d, d)),
        "wv": ninit(_key(key, "wv"), (d, d)),
        "wg": ninit(_key(key, "wg"), (d, d)),
        "wo": ninit(_key(key, "wo"), (d, d)),
        "w0": jnp.full((d,), -1.0, jnp.float32),  # base decay logit
        "w_lora_a": ninit(_key(key, "wla"), (d, DECAY_RANK)),
        "w_lora_b": ninit(_key(key, "wlb"), (DECAY_RANK, d), fan_in=DECAY_RANK) * 0.1,
        "u": jnp.zeros((h, dk), jnp.float32),  # current-token bonus
        # channel mix
        "cm_mix": jax.random.uniform(_key(key, "cmix"), (2, d)),
        "cm_k": ninit(_key(key, "cmk"), (d, cfg.d_ff)),
        "cm_v": ninit(_key(key, "cmv"), (cfg.d_ff, d), fan_in=cfg.d_ff),
        "cm_r": ninit(_key(key, "cmr"), (d, d)),
    }


def rwkv_axes(cfg):
    return {
        "mix": (None, "embed"),
        "wr": ("fsdp", "heads"),
        "wk": ("fsdp", "heads"),
        "wv": ("fsdp", "heads"),
        "wg": ("fsdp", "heads"),
        "wo": ("heads", "fsdp"),
        "w0": ("embed",),
        "w_lora_a": ("fsdp", None),
        "w_lora_b": (None, "embed"),
        "u": (None, None),  # (h, dk) is tiny; h may be 1 at smoke scale
        "cm_mix": (None, "embed"),
        "cm_k": ("fsdp", "mlp"),
        "cm_v": ("mlp", "fsdp"),
        "cm_r": ("fsdp", "embed"),
    }


def _shift(x, prev):
    """Token shift: x_{t-1}, with `prev` (B,1,d) as the t=0 predecessor."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _head_norm(y, eps=1e-5):
    return y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)


def _wkv_scan(r, k, v, w, u, s0, chunk: int):
    """r,k,v: (B,T,H,Dk); w: (B,T,H,Dk) decay in (0,1); s0: (B,H,Dk,Dv).

    Baseline (paper-faithful recurrence): one state update per token. Exact,
    but state traffic is O(T·Dk·Dv) HBM bytes — the memory-roofline driver
    identified in EXPERIMENTS.md §Perf.
    """
    b, t, h, dk = r.shape

    def step(s, inp):
        ri, ki, vi, wi = inp  # (B,H,Dk)
        kv = jnp.einsum("bhk,bhv->bhkv", ki, vi)
        y = jnp.einsum("bhk,bhkv->bhv", ri, s) + jnp.einsum(
            "bhk,hk,bhkv->bhv", ri, u, kv
        )
        s_new = s * wi[..., None] + kv
        return s_new, y

    xs = jax.tree.map(
        lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0), (r, k, v, w)
    )
    n = t
    q = min(chunk, n)
    while n % q != 0:
        q //= 2

    def chunk_body(s, inp_chunk):
        return lax.scan(step, s, inp_chunk)

    xs_c = jax.tree.map(lambda a: a.reshape((n // q, q) + a.shape[1:]), xs)
    s_end, ys = lax.scan(jax.checkpoint(chunk_body), s0.astype(jnp.float32), xs_c)
    y = jnp.moveaxis(ys.reshape((n,) + ys.shape[2:]), 0, 1)  # (B,T,H,Dv)
    return y, s_end


WKV_BLOCK = 16  # per-channel decay exponents bounded by BLOCK·|log w|_max < 88


def _wkv_blocked(r, k, v, w, u, s0, block: int = WKV_BLOCK):
    """Block-parallel WKV (GLA-style): one state update per BLOCK tokens.

    Within a block (Λ = exclusive cumsum log w from block start; Lb = total):
        y_i   = r̃_i·S + (r̃_i·k̂_j)_{j<i} v_j + ((r_i⊙u)·k_i) v_i
        S'    = diag(e^{Lb}) S + k̃ᵀ v
        r̃ = r⊙e^Λ (≤1),  k̂ = k⊙e^{-(Λ+log w)},  k̃ = k⊙e^{Lb-Λ-log w} (≤1)
    The only growing exponent, -(Λ+log w) ≤ BLOCK·|log w|_max, stays under
    fp32 overflow because `_decay` clamps per-step log-decay magnitude.
    HBM: state read/write every `block` steps instead of every step, plus
    O(block²) intra terms that live in registers/VMEM — memory roofline drops
    ~block×; flops rise by the (tiny) block² term. Exactness vs the scan
    baseline is tested to 1e-4.
    """
    b, t, h, dk = r.shape
    nb = t // block
    assert t % block == 0, (t, block)

    f32 = jnp.float32
    shp = (b, nb, block, h, dk)
    rb, kb, vb, wb = (
        a.astype(f32).reshape(shp) for a in (r, k, v, w)
    )
    logw = jnp.log(jnp.maximum(wb, 1e-38))  # (B,nb,S,H,C), <= 0
    lam = jnp.cumsum(logw, axis=2) - logw  # exclusive cumsum Λ
    lb_tot = lam[:, :, -1] + logw[:, :, -1]  # (B,nb,H,C)

    r_t = rb * jnp.exp(lam)
    k_hat = kb * jnp.exp(-(lam + logw))
    k_tl = kb * jnp.exp(lb_tot[:, :, None] - lam - logw)

    # intra-block causal pairs + current-token bonus
    a_pairs = jnp.einsum("bnihc,bnjhc->bnhij", r_t, k_hat)
    mask = jnp.tril(jnp.ones((block, block), bool), k=-1)
    a_pairs = jnp.where(mask[None, None, None], a_pairs, 0.0)
    a_bonus = jnp.einsum("bnihc,hc,bnihc->bnhi", rb, u.astype(f32), kb)
    y_intra = jnp.einsum("bnhij,bnjhv->bnihv", a_pairs, vb)
    y_intra = y_intra + a_bonus[..., None].transpose(0, 1, 3, 2, 4) * vb

    def body(s, inp):
        rt_n, ktl_n, v_n, lbt_n = inp  # (B,S,H,C), ..., (B,H,C)
        y_inter = jnp.einsum("bihc,bhcv->bihv", rt_n, s)
        s_new = s * jnp.exp(lbt_n)[..., None] + jnp.einsum("bjhc,bjhv->bhcv", ktl_n, v_n)
        return s_new, y_inter

    xs = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0), (r_t, k_tl, vb, lb_tot)
    )
    s_end, y_inter = lax.scan(body, s0.astype(f32), xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, t, h, dk), s_end


def _decay(params, zw):
    # log-decay magnitude clamped to exp(1.2)≈3.32/step: keeps the blocked
    # WKV's largest exponent at BLOCK·3.32≈53 < fp32 overflow (88); a decay of
    # e^-3.32 per step is already ≈0 over a block, so the cap is harmless.
    ww = params["w0"] + jnp.tanh(
        zw.astype(jnp.float32) @ params["w_lora_a"]
    ) @ params["w_lora_b"]
    return jnp.exp(-jnp.exp(jnp.clip(ww, -12.0, 1.2)))  # (…, d) in (0,1)


def rwkv_time_mix(cfg, params, x, shift_state=None, wkv_state=None, chunk: int = 256,
                  impl: str = "blocked"):
    b, t, d = x.shape
    h, dk = rwkv_dims(cfg)
    prev = shift_state if shift_state is not None else jnp.zeros((b, 1, d), x.dtype)
    xp = _shift(x, prev)
    mix = params["mix"].astype(x.dtype)
    zr, zk, zv, zw, zg = (x + (xp - x) * mix[i] for i in range(5))
    r = (zr @ params["wr"].astype(x.dtype)).reshape(b, t, h, dk)
    k = (zk @ params["wk"].astype(x.dtype)).reshape(b, t, h, dk)
    v = (zv @ params["wv"].astype(x.dtype)).reshape(b, t, h, dk)
    g = jax.nn.silu(zg @ params["wg"].astype(x.dtype))
    w = _decay(params, zw).reshape(b, t, h, dk)
    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, dk, dk), jnp.float32)
    impl = getattr(cfg, "wkv_impl", impl)
    if impl == "blocked" and t % WKV_BLOCK == 0 and t >= WKV_BLOCK:
        y, s_end = _wkv_blocked(r, k, v, w, params["u"], wkv_state)
    else:
        y, s_end = _wkv_scan(r, k, v, w, params["u"], wkv_state, chunk)
    y = _head_norm(y).reshape(b, t, d).astype(x.dtype) * g
    out = y @ params["wo"].astype(x.dtype)
    return out, (x[:, -1:, :], s_end)


def rwkv_channel_mix(cfg, params, x, shift_state=None):
    b, t, d = x.shape
    prev = shift_state if shift_state is not None else jnp.zeros((b, 1, d), x.dtype)
    xp = _shift(x, prev)
    mix = params["cm_mix"].astype(x.dtype)
    zk = x + (xp - x) * mix[0]
    zr = x + (xp - x) * mix[1]
    kk = jnp.square(jax.nn.relu(zk @ params["cm_k"].astype(x.dtype)))
    rr = jax.nn.sigmoid(zr @ params["cm_r"].astype(x.dtype))
    return rr * (kk @ params["cm_v"].astype(x.dtype)), x[:, -1:, :]


def rwkv_time_mix_step(cfg, params, x, shift_state, wkv_state):
    """One-token decode. x (B,1,d); shift (B,1,d); wkv (B,H,Dk,Dv)."""
    b, _, d = x.shape
    h, dk = rwkv_dims(cfg)
    xp = shift_state.astype(x.dtype)
    mix = params["mix"].astype(x.dtype)
    zr, zk, zv, zw, zg = (x + (xp - x) * mix[i] for i in range(5))
    r = (zr @ params["wr"].astype(x.dtype)).reshape(b, h, dk).astype(jnp.float32)
    k = (zk @ params["wk"].astype(x.dtype)).reshape(b, h, dk).astype(jnp.float32)
    v = (zv @ params["wv"].astype(x.dtype)).reshape(b, h, dk).astype(jnp.float32)
    g = jax.nn.silu(zg @ params["wg"].astype(x.dtype))
    w = _decay(params, zw).reshape(b, h, dk)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, wkv_state) + jnp.einsum(
        "bhk,hk,bhkv->bhv", r, params["u"], kv
    )
    s_new = wkv_state * w[..., None] + kv
    y = _head_norm(y).reshape(b, 1, d).astype(x.dtype) * g
    return y @ params["wo"].astype(x.dtype), x, s_new

"""Shared layers: norms, MLPs, embeddings, RoPE, init helpers.

Init convention: every module has `<mod>_init(key, cfg, ...) -> params` and
`<mod>_axes(cfg, ...) -> axes` (identical structure; leaves are tuples of
logical dim names consumed by repro.parallel.sharding).
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp


def _key(key, name: str):
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def ninit(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / max(fan_in, 1)) ** 0.5


def compute_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --- norms --------------------------------------------------------------------


def norm_init(key, d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_axes(d):
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"]).astype(dt)


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * params["scale"]).astype(dt)


def apply_norm(cfg, params, x):
    return rmsnorm(params, x) if cfg.norm == "rmsnorm" else layernorm(params, x)


def act_fn(cfg):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[cfg.act]


# --- gated MLP (SwiGLU family) --------------------------------------------------


def mlp_init(key, d_model, d_ff):
    return {
        "wi": ninit(_key(key, "wi"), (d_model, d_ff)),
        "wg": ninit(_key(key, "wg"), (d_model, d_ff)),
        "wo": ninit(_key(key, "wo"), (d_ff, d_model)),
    }


def mlp_axes():
    return {"wi": ("fsdp", "mlp"), "wg": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}


def mlp_apply(cfg, params, x):
    dt = x.dtype
    h = jnp.einsum("btd,df->btf", x, params["wi"].astype(dt))
    g = jnp.einsum("btd,df->btf", x, params["wg"].astype(dt))
    h = act_fn(cfg)(g) * h
    return jnp.einsum("btf,fd->btd", h, params["wo"].astype(dt))


# --- embeddings -----------------------------------------------------------------


def embed_init(key, vocab, d_model):
    return {"table": jax.random.normal(_key(key, "emb"), (vocab, d_model)) * 0.02}


def embed_axes():
    return {"table": ("vocab", "embed")}


def embed_apply(cfg, params, tokens):
    # gather; vocab is 'model'-sharded -> XLA turns this into a sharded
    # one-hot matmul / all-reduce under SPMD
    return params["table"].astype(compute_dtype(cfg))[tokens]


def unembed_apply(cfg, params, x):
    logits = jnp.einsum("btd,vd->btv", x, params["table"].astype(x.dtype))
    vpad = params["table"].shape[0]
    if vpad > cfg.vocab_size:
        # mask padding rows (never predicted, zero softmax mass)
        live = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vpad), 2) < cfg.vocab_size
        logits = jnp.where(live, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# --- RoPE ------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (B, T, H, Dh); positions: (B, T) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)

"""GQA/MQA attention: dense, query-chunked (memory-safe long-context), decode.

Layouts: q (B, T, H, Dh); k/v (B, S, Hkv, Dh); GQA groups G = H // Hkv.
The query-chunked path (`chunk > 0`) scans query blocks against the full
K/V — score working set is O(C·S) instead of O(T·S), which is what lets
prefill_32k lower within a v5e's HBM. Decode (T=1) always takes the dense
path (scores are O(S)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import _key, ninit, rmsnorm, rope

NEG_INF = -1e30


def attn_init(key, cfg, cross: bool = False):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ninit(_key(key, "wq"), (d, h * dh)),
        "wk": ninit(_key(key, "wk"), (d, hkv * dh)),
        "wv": ninit(_key(key, "wv"), (d, hkv * dh)),
        "wo": ninit(_key(key, "wo"), (h * dh, d), fan_in=h * dh),
    }
    if cfg.qk_norm:
        p["qn"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["kn"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def attn_axes(cfg):
    a = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    if cfg.qk_norm:
        a["qn"] = {"scale": (None,)}
        a["kn"] = {"scale": (None,)}
    return a


def project_q(cfg, params, x, positions, apply_rope=True):
    b, t, _ = x.shape
    q = jnp.einsum("btd,dh->bth", x, params["wq"].astype(x.dtype))
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["qn"], q)
    if apply_rope:
        q = rope(q, positions, cfg.rope_theta)
    return q


def project_kv(cfg, params, x, positions, apply_rope=True):
    b, s, _ = x.shape
    k = jnp.einsum("btd,dh->bth", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dh->bth", x, params["wv"].astype(x.dtype))
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(params["kn"], k)
    if apply_rope:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def _attend_dense(cfg, q, k, v, q_pos, k_pos, k_valid, causal):
    b, t, h, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, dh)
    sdt = jnp.bfloat16 if cfg.softmax_dtype == "bfloat16" else jnp.float32
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(sdt)
    scores = scores / (dh**0.5)
    mask = jnp.ones((b, 1, 1, t, s), bool)
    if causal:
        mask &= (k_pos[:, None, :] <= q_pos[:, :, None])[:, None, None, :, :]
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, jnp.asarray(NEG_INF, sdt))
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgts,bskd->btkgd", w, v)
    return ctx.reshape(b, t, h, dh)


def _attend_chunked(cfg, q, k, v, q_pos, k_pos, k_valid, causal, chunk):
    b, t, h, dh = q.shape
    if t % chunk != 0 or t <= chunk:
        return _attend_dense(cfg, q, k, v, q_pos, k_pos, k_valid, causal)
    nc = t // chunk
    qc = q.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)  # (nc, B, C, H, Dh)
    pc = q_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    def one(args):
        qi, pi = args
        return _attend_dense(cfg, qi, k, v, pi, k_pos, k_valid, causal)

    ctx = lax.map(one, (qc, pc))  # (nc, B, C, H, Dh), O(C*S) live scores
    return ctx.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dh)


def attend(cfg, q, k, v, q_pos, k_pos, k_valid=None, causal=True):
    if cfg.attn_chunk and q.shape[1] > cfg.attn_chunk:
        return _attend_chunked(cfg, q, k, v, q_pos, k_pos, k_valid, causal, cfg.attn_chunk)
    return _attend_dense(cfg, q, k, v, q_pos, k_pos, k_valid, causal)


def out_proj(cfg, params, ctx):
    b, t = ctx.shape[:2]
    return jnp.einsum("bth,hd->btd", ctx.reshape(b, t, -1), params["wo"].astype(ctx.dtype))


def self_attention(cfg, params, x, positions, k_valid=None, causal=None):
    """Full self-attention over x (training / prefill)."""
    causal = cfg.causal if causal is None else causal
    q = project_q(cfg, params, x, positions)
    k, v = project_kv(cfg, params, x, positions)
    ctx = attend(cfg, q, k, v, positions, positions, k_valid, causal)
    return out_proj(cfg, params, ctx)


def cross_attention(cfg, params, x, enc_kv, positions, enc_valid=None):
    """Decoder->encoder attention; enc_kv = (k, v) projected encoder states."""
    q = project_q(cfg, params, x, positions, apply_rope=False)
    k, v = enc_kv
    s = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (x.shape[0], s))
    ctx = attend(cfg, q, k, v, positions, k_pos, enc_valid, causal=False)
    return out_proj(cfg, params, ctx)


def decode_self_attention(cfg, params, x, cache_k, cache_v, position):
    """One-token decode: x (B, 1, d); cache (B, S, Hkv, Dh); position (B,).

    Returns (out, new_k, new_v): caller writes new_k/new_v into the cache at
    `position` (functional update lives in serve/engine.py).
    """
    b = x.shape[0]
    pos = position[:, None]  # (B, 1)
    q = project_q(cfg, params, x, pos)
    k_new, v_new = project_kv(cfg, params, x, pos)
    s = cache_k.shape[1]
    idx = jnp.arange(s, dtype=jnp.int32)[None]  # (1, S)

    # in-place cache write (donation-aliasable, unlike a full-cache select)
    def upd(c, n, p):
        return lax.dynamic_update_slice(c, n, (p, jnp.int32(0), jnp.int32(0)))

    k = jax.vmap(upd)(cache_k, k_new.astype(cache_k.dtype), position)
    v = jax.vmap(upd)(cache_v, v_new.astype(cache_v.dtype), position)
    k_pos = jnp.broadcast_to(idx, (b, s))
    k_valid = idx <= pos
    ctx = attend(cfg, q, k, v, pos, k_pos, k_valid, causal=False)
    return out_proj(cfg, params, ctx), k, v

"""LM assembly: init/axes/forward/loss for every assigned architecture family.

Layer stacks are `lax.scan` over stacked per-layer params (HLO is O(1) in
depth). Families:
  dense | vlm       scan of attn blocks
  moe               scan of attn+MoE blocks (secure-shuffle dispatch inside)
  ssm (rwkv6)       scan of rwkv blocks
  hybrid (zamba2)   scan of mamba blocks with a weight-SHARED attention block
                    injected every `attn_every` layers via lax.cond
  audio (whisper)   encoder scan + decoder scan with cross-attention; the
                    conv/mel frontend is a stub: inputs are frame embeddings
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import blocks as B
from repro.models.layers import (
    _key,
    apply_norm,
    compute_dtype,
    embed_apply,
    embed_axes,
    embed_init,
    norm_axes,
    norm_init,
    unembed_apply,
)


def _stack_init(key, cfg, kind, n, n_model=1):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: B.block_init(k, cfg, kind, n_model))(keys)


def _stack_axes(cfg, kind):
    ax = B.block_axes(cfg, kind)
    return jax.tree.map(
        lambda a: ("layers",) + a,
        ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )


def main_kind(cfg) -> str:
    return {
        "dense": "attn",
        "vlm": "attn",
        "moe": "moe",
        "ssm": "rwkv",
        "hybrid": "mamba",
        "audio": "dec_cross",
    }[cfg.family]


def init_params(cfg, key, n_model: int = 1):
    p = {"embed": embed_init(_key(key, "embed"), cfg.padded_vocab, cfg.d_model)}
    if cfg.family == "audio":
        p["encoder"] = _stack_init(_key(key, "enc"), cfg, "enc", cfg.n_encoder_layers)
        p["enc_norm"] = norm_init(key, cfg.d_model)
        p["decoder"] = _stack_init(_key(key, "dec"), cfg, "dec_cross", cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack_init(_key(key, "layers"), cfg, "mamba", cfg.n_layers)
        p["shared_attn"] = B.block_init(_key(key, "shared"), cfg, "attn")
    else:
        p["layers"] = _stack_init(_key(key, "layers"), cfg, main_kind(cfg), cfg.n_layers,
                                  n_model)
    p["final_norm"] = norm_init(key, cfg.d_model)
    return p


def param_axes(cfg):
    a = {"embed": embed_axes()}
    if cfg.family == "audio":
        a["encoder"] = _stack_axes(cfg, "enc")
        a["enc_norm"] = norm_axes(cfg.d_model)
        a["decoder"] = _stack_axes(cfg, "dec_cross")
    elif cfg.family == "hybrid":
        a["layers"] = _stack_axes(cfg, "mamba")
        a["shared_attn"] = B.block_axes(cfg, "attn")
    else:
        a["layers"] = _stack_axes(cfg, main_kind(cfg))
    a["final_norm"] = norm_axes(cfg.d_model)
    return a


# --- forward -------------------------------------------------------------------


def _dp(mesh):
    if mesh is None:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _seq_ax(cfg, mesh, t: int):
    """'model' when context parallelism is on and the length divides."""
    if (
        mesh is not None
        and getattr(cfg, "shard_strategy", "tp") == "dp_sp"
        and "model" in mesh.axis_names
        and t % mesh.shape["model"] == 0
        and t >= mesh.shape["model"]
    ):
        return "model"
    return None


def constrain_act(cfg, mesh, h):
    """(B, T, d) activation constraint under the arch's shard strategy."""
    if mesh is None:
        return h
    return _constrain(h, mesh, P(_dp(mesh), _seq_ax(cfg, mesh, h.shape[1]), None))


def _remat_groups(cfg, n_layers: int) -> int:
    """Outer group count for two-level (sqrt-L) remat: the scan saves only
    G ≈ sqrt(L) group-boundary activations; each group recomputes its layers
    during backward. Returns 1 (plain per-layer remat) when not worthwhile."""
    if cfg.remat != "sqrt" or n_layers < 12:
        return 1
    best, best_cost = 1, float("inf")
    for g in range(2, n_layers + 1):
        if n_layers % g:
            continue
        cost = g + n_layers // g  # boundaries + recompute span
        if cost < best_cost:
            best, best_cost = g, cost
    return best


def _scan_grouped(cfg, stack, x, layer_step, mesh, names=()):
    """lax.scan over L layers with optional two-level remat.

    layer_step(carry, p) -> carry  (carry may be a tuple; x is carry here)
    `names` are checkpoint_name'd intermediates kept at BOTH remat levels
    (collective outputs must not be replayed by backward).
    """
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    groups = _remat_groups(cfg, n_layers)
    body = B.remat_wrap(cfg, layer_step, names=names)

    def inner(carry, p):
        return body(carry, p), ()

    if groups == 1:
        out, _ = lax.scan(inner, x, stack)
        return out

    per = n_layers // groups
    gstack = jax.tree.map(lambda a: a.reshape((groups, per) + a.shape[1:]), stack)

    def group_fn(carry, gp):
        out, _ = lax.scan(inner, carry, gp)
        return out

    if names:
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.save_only_these_names(*names)
        )
    else:
        group_fn = jax.checkpoint(group_fn)

    def group_step(carry, gp):
        return group_fn(carry, gp), ()

    out, _ = lax.scan(group_step, x, gstack)
    return out


def _scan_attn(cfg, stack, x, positions, mesh, causal=None):
    def step(h, p):
        h = B.apply_attn_block(cfg, p, h, positions, causal=causal)
        return constrain_act(cfg, mesh, h)

    return _scan_grouped(cfg, stack, x, step, mesh)


def _scan_moe(cfg, stack, x, positions, mesh, secure=None):
    dp = _dp(mesh) or ()

    def step(carry, p):
        h, aux, dropped = carry
        h, a, d = B.apply_moe_block(cfg, p, h, positions, mesh=mesh, dp_spec=dp,
                                    secure=secure)
        h = constrain_act(cfg, mesh, h)
        return (h, aux + a, dropped + d)

    names = ("moe_recv", "moe_back") if cfg.moe_remat == "save_shuffle" else ()
    x, aux, dropped = _scan_grouped(
        cfg, stack, (x, jnp.float32(0.0), jnp.int32(0)), step, mesh, names=names
    )
    return x, aux, dropped


def _scan_rwkv(cfg, stack, x, mesh):
    def step(h, p):
        h, _states = B.apply_rwkv_block(cfg, p, h)
        return constrain_act(cfg, mesh, h)

    return _scan_grouped(cfg, stack, x, step, mesh)


def _scan_hybrid(cfg, params, x, positions, mesh):
    """Mamba scan in groups of `attn_every`, the weight-SHARED attention block
    applied between groups (grouped rather than lax.cond-in-scan: every op is
    statically counted, and no branch executes wastefully)."""
    shared = params["shared_attn"]
    every = cfg.attn_every or (cfg.n_layers + 1)
    n_groups = cfg.n_layers // every

    mamba_body = B.remat_wrap(cfg, lambda p, h: B.apply_mamba_block(cfg, p, h)[0])

    def scan_stack(h, stack):
        def step(hh, p):
            return constrain_act(cfg, mesh, mamba_body(p, hh)), ()

        return lax.scan(step, h, stack)[0]

    attn_body = B.remat_wrap(cfg, lambda h: B.apply_attn_block(cfg, shared, h, positions))

    @jax.checkpoint
    def group(h, sl):
        h = scan_stack(h, sl)
        return constrain_act(cfg, mesh, attn_body(h))

    for g in range(n_groups):
        sl = jax.tree.map(lambda a: a[g * every : (g + 1) * every], params["layers"])
        x = group(x, sl)
    if cfg.n_layers % every:
        sl = jax.tree.map(lambda a: a[n_groups * every :], params["layers"])
        x = scan_stack(x, sl)
    return x


def _scan_dec_cross(cfg, stack, x, positions, enc_kv_stack, mesh):
    """Decoder scan; per-layer cross-attention K/V precomputed from encoder."""

    def block(p, ekv, h):
        return B.apply_dec_cross_block(cfg, p, h, positions, ekv)

    body = B.remat_wrap(cfg, block)

    def step(h, inp):
        p, ekv = inp
        h = constrain_act(cfg, mesh, body(p, ekv, h))
        return h, ()

    x, _ = lax.scan(step, x, (stack, enc_kv_stack))
    return x


def encode_audio(cfg, params, frames, mesh=None):
    """frames: (B, S_enc, d_model) — precomputed frontend embeddings (stub).
    Returns per-decoder-layer cross K/V stack."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h = frames.astype(compute_dtype(cfg))
    h = _scan_attn(cfg, params["encoder"], h, pos, mesh, causal=False)
    h = apply_norm(cfg, params["enc_norm"], h)

    def proj(p):
        return attn.project_kv(cfg, p["xattn"], h, pos, apply_rope=False)

    return jax.vmap(proj)(params["decoder"])  # (L, ...) k/v stacks


def forward(cfg, params, batch, mesh=None, secure_moe=None):
    """batch: {"tokens": (B,T) int32 [, "frames": (B,S,d) for audio]}.
    Returns (logits (B,T,V), aux dict)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    dp = _dp(mesh)
    x = constrain_act(cfg, mesh, embed_apply(cfg, params["embed"], tokens))
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    aux = {"moe_aux": jnp.float32(0.0), "moe_dropped": jnp.int32(0)}

    if cfg.family == "audio":
        enc_kv = encode_audio(cfg, params, batch["frames"], mesh)
        x = _scan_dec_cross(cfg, params["decoder"], x, positions, enc_kv, mesh)
    elif cfg.family == "hybrid":
        x = _scan_hybrid(cfg, params, x, positions, mesh)
    elif cfg.family == "ssm":
        x = _scan_rwkv(cfg, params["layers"], x, mesh)
    elif cfg.family == "moe":
        x, moe_aux, dropped = _scan_moe(cfg, params["layers"], x, positions, mesh,
                                        secure=secure_moe)
        aux = {"moe_aux": moe_aux / cfg.n_layers, "moe_dropped": dropped}
    else:
        x = _scan_attn(cfg, params["layers"], x, positions, mesh)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params["embed"], x)
    if mesh is not None and getattr(cfg, "shard_strategy", "tp") == "dp_sp":
        logits = _constrain(logits, mesh, P(dp, _seq_ax(cfg, mesh, logits.shape[1]), None))
    else:
        model_ax = "model" if (mesh is not None and "model" in mesh.axis_names) else None
        logits = _constrain(logits, mesh, P(dp, None, model_ax))
    return logits, aux


def loss_fn(cfg, params, batch, mesh=None, secure_moe=None, aux_coef: float = 0.01):
    """Next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = forward(cfg, params, batch, mesh, secure_moe)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
    if mask.shape[1] == batch["tokens"].shape[1]:
        mask = mask[:, 1:]
    nll = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll + aux_coef * aux["moe_aux"]
    metrics = {"nll": nll, **aux}
    return loss, metrics

"""Mixture-of-Experts with the paper's secure MapReduce shuffle as dispatch.

The paper's pipeline *is* expert parallelism:
    map      = router (token -> top-k expert keys)
    shuffle  = all_to_all keyed by expert id  (paper: hash(key) % rcount)
    reduce   = expert FFN + gate-weighted combine
`dispatch="shuffle"` runs exactly this inside shard_map over the 'model'
axis (experts sharded E/axis, sequence sharded over the same axis while in
the block), reusing `core.shuffle.bucket_pack` / `keyed_all_to_all` — with
optional ChaCha20 on the expert payloads (`secure_moe`): ciphertext crosses
ICI, plaintext exists only chip-locally. `dispatch="dense"` is the same
token->expert packing without collectives, left to XLA's auto-SPMD (oracle
path for equivalence tests).

Token dropping: per-expert capacity = ceil(k·n_loc/E_pad · capacity_factor),
dropped tokens pass through (standard capacity-factor semantics); the drop
count is returned as aux.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.shuffle import SecureShuffleConfig, bucket_pack, keyed_all_to_all
from repro.models.layers import _key, act_fn, ninit


def padded_experts(cfg, n_model: int = 1) -> int:
    e = cfg.n_experts
    return -(-e // n_model) * n_model


def moe_init(key, cfg, n_model: int = 1):
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, padded_experts(cfg, n_model)
    p = {
        "router": ninit(_key(key, "router"), (d, e)),
        "wi": ninit(_key(key, "ewi"), (e, d, f)),
        "wg": ninit(_key(key, "ewg"), (e, d, f)),
        "wo": ninit(_key(key, "ewo"), (e, f, d), fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff or cfg.n_shared_experts * f
        p["shared"] = {
            "wi": ninit(_key(key, "swi"), (d, fs)),
            "wg": ninit(_key(key, "swg"), (d, fs)),
            "wo": ninit(_key(key, "swo"), (fs, d), fan_in=fs),
            "gate": ninit(_key(key, "sgate"), (d, 1)),
        }
    return p


def moe_axes(cfg):
    fs = "fsdp" if getattr(cfg, "moe_fsdp", True) else None
    a = {
        "router": ("fsdp", None),
        "wi": ("experts", fs, "expert_mlp"),
        "wg": ("experts", fs, "expert_mlp"),
        "wo": ("experts", "expert_mlp", fs),
    }
    if cfg.n_shared_experts:
        a["shared"] = {
            "wi": ("fsdp", "mlp"),
            "wg": ("fsdp", "mlp"),
            "wo": ("mlp", "fsdp"),
            "gate": ("fsdp", None),
        }
    return a


def _route(cfg, router_w, x2, e_pad):
    """x2: (n, d) -> gates (n, k), experts (n, k)."""
    logits = jnp.einsum("nd,de->ne", x2, router_w.astype(x2.dtype)).astype(jnp.float32)
    # padding experts never win
    if e_pad > cfg.n_experts:
        neg = jnp.full((x2.shape[0], e_pad - cfg.n_experts), -1e30, jnp.float32)
        logits = jnp.concatenate([logits[:, : cfg.n_experts], neg], axis=1)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = lax.top_k(probs, cfg.n_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # aux: load-balance statistics (Switch-style)
    load = jnp.mean(jax.nn.one_hot(eidx[:, 0], e_pad, dtype=jnp.float32), axis=0)
    importance = jnp.mean(probs, axis=0)
    aux_loss = e_pad * jnp.sum(load * importance)
    return gates.astype(x2.dtype), eidx.astype(jnp.int32), aux_loss


def _expert_ffn(cfg, wi, wg, wo, xe):
    """xe: (E_loc, C, d) -> (E_loc, C, d), batched over local experts."""
    dt = xe.dtype
    h = jnp.einsum("ecd,edf->ecf", xe, wi.astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
    return jnp.einsum("ecf,efd->ecd", act_fn(cfg)(g) * h, wo.astype(dt))


def _shared_expert(cfg, sp, x2):
    dt = x2.dtype
    h = jnp.einsum("nd,df->nf", x2, sp["wi"].astype(dt))
    g = jnp.einsum("nd,df->nf", x2, sp["wg"].astype(dt))
    y = jnp.einsum("nf,fd->nd", act_fn(cfg)(g) * h, sp["wo"].astype(dt))
    gate = jax.nn.sigmoid(
        jnp.einsum("nd,do->no", x2, sp["gate"].astype(dt)).astype(jnp.float32)
    ).astype(dt)
    return y * gate


def _capacity(cfg, n_tokens: int, e_pad: int) -> int:
    c = int(n_tokens * cfg.n_experts_per_tok / e_pad * cfg.capacity_factor) + 1
    return max(4, -(-c // 4) * 4)


def _moe_local(cfg, params, x2, e_pad: int, capacity: int | None = None):
    """Single-domain path: pack -> batched expert FFN -> combine (no comms)."""
    n, d = x2.shape
    gates, eidx, aux = _route(cfg, params["router"], x2, e_pad)
    k = cfg.n_experts_per_tok
    cap = capacity or _capacity(cfg, n, e_pad)

    entry_expert = eidx.reshape(-1)
    entry_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    entry_keys = jnp.arange(n * k, dtype=jnp.int32)
    _, packed, dropped, pos = bucket_pack(
        entry_keys, entry_expert, {"x": x2[entry_token]}, e_pad, cap,
        return_positions=True,
    )
    y_buf = _expert_ffn(cfg, params["wi"], params["wg"], params["wo"], packed["x"])
    flat = jnp.concatenate([y_buf.reshape(e_pad * cap, d), jnp.zeros((1, d), y_buf.dtype)])
    contrib = flat[pos] * gates.reshape(-1)[:, None]
    y = jax.ops.segment_sum(contrib, entry_token, num_segments=n)
    if cfg.n_shared_experts:
        y = y + _shared_expert(cfg, params["shared"], x2)
    return y.astype(x2.dtype), aux, dropped


def _moe_decode_body(x, router, wi, wg, wo, shared, *, cfg, n_model: int, all_axes):
    """Replicated-dispatch EP for short sequences (decode): every rank holds
    the same tokens, computes only its local experts, partial sums psum'd."""
    b, t, d = x.shape
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    e_pad = padded_experts(cfg, n_model)
    e_loc = e_pad // n_model
    my_first = lax.axis_index("model").astype(jnp.int32) * e_loc

    gates, eidx, aux = _route(cfg, router, x2, e_pad)
    k = cfg.n_experts_per_tok
    entry_expert = eidx.reshape(-1)
    entry_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    mine = (entry_expert >= my_first) & (entry_expert < my_first + e_loc)
    entry_keys = jnp.where(mine, jnp.arange(n * k, dtype=jnp.int32), -1)
    cap = max(4, n)  # worst case: all local tokens on one local expert
    _, packed, dropped, pos = bucket_pack(
        entry_keys, entry_expert - my_first, {"x": x2[entry_token]}, e_loc, cap,
        return_positions=True,
    )
    ye = _expert_ffn(cfg, wi, wg, wo, packed["x"])
    flat = jnp.concatenate([ye.reshape(e_loc * cap, d), jnp.zeros((1, d), ye.dtype)])
    contrib = flat[pos] * gates.reshape(-1)[:, None]
    y = jax.ops.segment_sum(contrib, entry_token, num_segments=n)
    y = lax.psum(y, "model")
    if cfg.n_shared_experts:
        y = y + _shared_expert(cfg, shared, x2)
    return (
        y.reshape(b, t, d).astype(x.dtype),
        lax.pmean(aux, all_axes),
        lax.psum(dropped, all_axes) // n_model,  # replicated over model ranks
    )


def _moe_shuffle_body(x, router, wi, wg, wo, shared, *, cfg, n_model: int, all_axes,
                      secure: SecureShuffleConfig | None):
    """shard_map body: x (B_loc, T_loc, d); experts sharded over 'model'."""
    b, t, d = x.shape
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    e_pad = padded_experts(cfg, n_model)
    e_loc = e_pad // n_model
    gates, eidx, aux = _route(cfg, router, x2, e_pad)
    k = cfg.n_experts_per_tok
    cap = _capacity(cfg, n, e_pad)

    # --- map: emit (expert_key, token_vector); shuffle: hash(key) = key ------
    entry_expert = eidx.reshape(-1)
    entry_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    entry_keys = jnp.arange(n * k, dtype=jnp.int32)
    _, packed, dropped, pos = bucket_pack(
        entry_keys, entry_expert, {"x": x2[entry_token]}, e_pad, cap,
        return_positions=True,
    )
    send = packed["x"].reshape(n_model, e_loc * cap, d)  # dest-device-major
    recv = keyed_all_to_all({"x": send}, "model", secure)["x"]  # (n_model, e_loc*cap, d)
    recv = checkpoint_name(recv, "moe_recv")  # saveable under moe_remat=save_shuffle

    # --- reduce: local experts over tokens from every source ------------------
    xe = recv.reshape(n_model, e_loc, cap, d).transpose(1, 0, 2, 3).reshape(
        e_loc, n_model * cap, d
    )
    ye = _expert_ffn(cfg, wi, wg, wo, xe)

    # --- return shuffle (the reducer->client leg) ------------------------------
    back = ye.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3).reshape(
        n_model, e_loc * cap, d
    )
    sec_back = None
    if secure is not None:
        sec_back = SecureShuffleConfig(
            key_words=secure.key_words,
            nonce_words=secure.nonce_words,
            counter0=secure.counter0 + (1 << 20),
        )
    got = checkpoint_name(
        keyed_all_to_all({"x": back}, "model", sec_back)["x"], "moe_back"
    ).reshape(e_pad * cap, d)

    flat = jnp.concatenate([got, jnp.zeros((1, d), got.dtype)])
    contrib = flat[pos] * gates.reshape(-1)[:, None]
    y = jax.ops.segment_sum(contrib, entry_token, num_segments=n)
    if cfg.n_shared_experts:
        y = y + _shared_expert(cfg, shared, x2)
    return (
        y.reshape(b, t, d).astype(x.dtype),
        lax.pmean(aux, all_axes),
        lax.psum(dropped, all_axes),
    )


def moe_apply(cfg, params, x, *, mesh=None, dp_spec=("pod", "data"),
              secure: SecureShuffleConfig | None = None):
    """x: (B, T, d). Uses shuffle dispatch when cfg.moe_dispatch=='shuffle'
    and a mesh with a 'model' axis is provided; else the local/XLA-auto path.
    Sequences shorter than the model axis (decode) use replicated-dispatch EP.
    """
    if cfg.moe_dispatch == "shuffle" and mesh is not None and "model" in mesh.axis_names:
        n_model = mesh.shape["model"]
        dp = tuple(a for a in (dp_spec if isinstance(dp_spec, tuple) else (dp_spec,))
                   if a in mesh.axis_names) or None
        all_axes = ((dp or ()) if isinstance(dp, tuple) else (dp,)) + ("model",)
        shared = params.get("shared", {"_": jnp.zeros((1,), jnp.float32)})
        seq_shardable = x.shape[1] % n_model == 0 and x.shape[1] >= n_model
        if seq_shardable:
            body = partial(_moe_shuffle_body, cfg=cfg, n_model=n_model,
                           all_axes=all_axes, secure=secure)
            x_spec = P(dp, "model", None)
        else:
            body = partial(_moe_decode_body, cfg=cfg, n_model=n_model, all_axes=all_axes)
            x_spec = P(dp, None, None)
        fn = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(
                x_spec,                      # x: batch over dp (+ seq over model)
                P(None, None),               # router replicated
                P("model", None, None),      # experts sharded
                P("model", None, None),
                P("model", None, None),
                jax.tree.map(lambda _: P(), shared),
            ),
            out_specs=(x_spec, P(), P()),
            check_vma=False,
        )
        return fn(x, params["router"], params["wi"], params["wg"], params["wo"], shared)

    b, t, d = x.shape
    e_pad = params["wi"].shape[0]
    y, aux, dropped = _moe_local(cfg, params, x.reshape(-1, d), e_pad)
    return y.reshape(b, t, d), aux, dropped

"""repro — secure MapReduce substrate for multi-pod JAX training/serving.

Reproduction (TPU-adapted) of: Pires, Gavril, Felber, Onica, Pasin,
"A lightweight MapReduce framework for secure processing with SGX" (2017).

Layers (bottom-up):
  crypto/    ChaCha20-CTR cipher, MAC, key provisioning ("attestation")
  kernels/   Pallas TPU kernels (chacha20 keystream/XOR, fused k-means assign)
  core/      the secure MapReduce engine (map/combine/shuffle/reduce) +
             SecVM (encrypted-bytecode UDFs) + SecurePager (EPC analogue)
  pubsub/    SCBR content-based router with in-enclave subscription matching
  runtime/   simulated multi-node cluster: scheduling, fault tolerance
  models/    the 10 assigned architectures (dense / MoE / hybrid / ssm / ...)
  train/ serve/ optim/ data/ parallel/ checkpoint/   framework substrates
  launch/    production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "0.1.0"

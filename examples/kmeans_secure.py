"""The paper's evaluation workload: secure k-means, convergence + overheads.

Reproduces the §V methodology at CPU scale: convergence under the diag/1000
threshold (Figs. 5-6), the 4-combo encryption x enclave overhead sweep
(Fig. 9), and the paging cliff (Fig. 8) via the SecurePager.

Run:  PYTHONPATH=src python examples/kmeans_secure.py
"""

import numpy as np

import jax

from repro.compat import make_mesh
from repro.core.kmeans import generate_points, kmeans_fit
from repro.core.paging import SecurePager
from repro.core.shuffle import SecureShuffleConfig
from repro.crypto import chacha
from repro.runtime.jobs import make_cluster, run_kmeans
from repro.runtime.node import SecurityPolicy
from repro.runtime.sim import TimingModel


def main():
    mesh = make_mesh((1,), ("data",))
    pts, true_centers = generate_points(20000, 10, seed=0, spread=0.05)

    print("=== convergence (paper Figs. 5-6) ===")
    secure = SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x02" * 12),
    )
    res = kmeans_fit(pts, 10, mesh, secure=secure, init="farthest")
    print(f"diag/1000 threshold: converged in {res.n_iter} iterations "
          f"({res.n_dispatches} fused host dispatches via the convergence-aware "
          f"driver; {res.n_rounds_dispatched} rounds dispatched, halt_fn masked "
          f"{res.n_rounds_dispatched - res.n_iter} post-convergence rounds on device), "
          f"final shift {res.center_shift[-1]:.2e}, inertia {res.inertia:.1f}")
    d = np.linalg.norm(np.asarray(res.centers)[:, None] - true_centers[None], axis=-1)
    print(f"max distance to a true center: {d.min(axis=0).max():.4f}")

    print("\n=== encryption x enclave overheads (paper Fig. 9) ===")
    times = {}
    for encl in (False, True):
        for enc in (False, True):
            cluster, client, _ = make_cluster(
                6, policy=SecurityPolicy(encryption=enc, enclave=encl),
                timing=TimingModel(epc_budget_bytes=32 << 20),
            )
            _, hist = run_kmeans(cluster, client, pts[:400], 5, n_mappers=4,
                                 n_reducers=2, max_iter=2, threshold=0.0)
            times[(encl, enc)] = np.mean([h["elapsed"] for h in hist])
    enc_ovh = 0.5 * ((times[(0, 1)] / times[(0, 0)] - 1) + (times[(1, 1)] / times[(1, 0)] - 1))
    encl_ovh = 0.5 * ((times[(1, 0)] / times[(0, 0)] - 1) + (times[(1, 1)] / times[(0, 1)] - 1))
    print(f"encryption overhead: {enc_ovh*100:.1f}%   (paper: ~5%)")
    print(f"enclave overhead:    {encl_ovh*100:.1f}%  (paper: ~30% inside EPC)")

    print("\n=== paging cliff (paper Fig. 8) ===")
    for ws_pages in (16, 64, 512):
        pager = SecurePager(budget_bytes=256 * 1024, key=b"\x07" * 32)
        for i in range(ws_pages):
            pager.store(f"p{i}", b"\0" * 4096)
        for i in range(ws_pages):
            pager.load(f"p{i}")
        print(f"working set {ws_pages*4096//1024:5d} KiB vs 256 KiB budget: "
              f"{pager.stats.bytes_encrypted + pager.stats.bytes_decrypted:9d} bytes paged")


if __name__ == "__main__":
    main()

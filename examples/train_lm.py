"""End-to-end training driver: secure-ingest LM training with checkpoints.

Trains a reduced config of any assigned architecture on synthetic structured
tokens for a few hundred steps, with the paper's data path (batches encrypted
on the host, decrypted in-graph), MAC-verified checkpointing, and restart.

Run:  PYTHONPATH=src python examples/train_lm.py --arch rwkv6-1.6b --steps 200
      PYTHONPATH=src python examples/train_lm.py --arch granite-moe-3b-a800m
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.crypto.keys import make_session_keys
from repro.data.pipeline import SecureShardedSource
from repro.data.synthetic import synthetic_tokens
from repro.models.lm import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import SecureIngest, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family == "audio":
        raise SystemExit("audio arch: use serve_lm.py (training driver is LM-style)")
    mesh = make_mesh((1,), ("data",))

    session = make_session_keys(b"\x42" * 32)
    ingest = SecureIngest(key_words=session.words("data"),
                          nonce_words=session.nonce_words("data", 0))
    toks = synthetic_tokens(200_000, cfg.vocab_size, seed=0)
    src = SecureShardedSource(toks, batch=args.batch, seq=args.seq, session=session)

    step_fn, _, _ = make_train_step(
        cfg, mesh, secure_ingest=ingest, peak_lr=1e-3, warmup=20,
        total_steps=args.steps, donate=False,
    )
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    mgr = CheckpointManager(args.ckpt_dir)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.2f}M "
          f"secure_ingest=on vocab={cfg.vocab_size}")

    t0 = time.perf_counter()
    first_loss = None
    for i in range(args.steps):
        batch = src.next_batch()  # ciphertext + counter
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        if i == 0:
            first_loss = float(metrics["loss"])
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")
        if (i + 1) % args.ckpt_every == 0:
            path = mgr.save(i + 1, (params, opt),
                            extra={"step": i + 1, "data_cursor": src.state})
            print(f"  checkpoint -> {path}")
    dt = time.perf_counter() - t0
    final_loss = float(metrics["loss"])
    print(f"\n{args.steps} steps in {dt:.1f}s ({dt/args.steps*1e3:.0f} ms/step); "
          f"loss {first_loss:.3f} -> {final_loss:.3f}")
    assert final_loss < first_loss, "training should reduce loss"


if __name__ == "__main__":
    main()

"""Quickstart: the paper's word-count example on both execution levels.

1. Cluster level — the full pub/sub protocol: hiring, encrypted code/data
   provisioning, mapper-side shuffle, EOS counting (paper Figs. 3-4), with
   the user logic shipped as a <30-LOC script (paper Listings 1-2).
2. Device level — the same job as one jitted shard_map pipeline with the
   shuffle payload ChaCha20-encrypted on the wire.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax

from repro.compat import make_mesh
from repro.core.shuffle import SecureShuffleConfig
from repro.core.wordcount import wordcount
from repro.crypto import chacha
from repro.runtime.jobs import WORDCOUNT_MAP, WORDCOUNT_REDUCE, make_cluster, run_wordcount

LINES = [
    "the quick brown fox jumps over the lazy dog",
    "mapreduce inside enclaves keeps the data private",
    "the router only ever sees ciphertext",
] * 5


def main():
    print("=== cluster level (pub/sub protocol, simulated nodes) ===")
    print(f"user map script:\n{WORDCOUNT_MAP}")
    cluster, client, _ = make_cluster(8)
    counts, info = run_wordcount(cluster, client, LINES, n_mappers=5, n_reducers=3)
    top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
    print(f"job finished in {info['elapsed']*1e3:.2f} virtual ms; top words: {top}")
    st = cluster.router.stats
    print(f"router: {st.publications} publications, {st.deliveries} deliveries, "
          f"{st.wire_bytes} wire bytes (all payloads encrypted)")

    print("\n=== device level (shard_map engine, encrypted all_to_all) ===")
    vocab = 1000
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, vocab, 20000, dtype=np.int32)
    mesh = make_mesh((1,), ("data",))
    secure = SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x01" * 12),
    )
    hist, dropped = wordcount(tokens, vocab, mesh, secure=secure)
    assert int(dropped) == 0
    ref = np.bincount(tokens, minlength=vocab)
    np.testing.assert_array_equal(np.asarray(hist), ref)
    print(f"token histogram verified over {len(tokens)} tokens, 0 dropped pairs")


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill + sampled decode on any assigned arch.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b --tokens 32
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import init_params
from repro.serve.engine import decode_step, init_cache, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    b, tp = args.batch, args.prompt_len
    smax = tp + args.tokens + 1

    key = jax.random.key(1)
    prompts = jax.random.randint(key, (b, tp), 0, cfg.vocab_size, jnp.int32)
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)

    cache = init_cache(cfg, b, smax)
    dec = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(cfg, params, prompts, cache, frames=frames)
    t_prefill = time.perf_counter() - t0

    out = []
    cur = None
    t0 = time.perf_counter()
    for i in range(args.tokens):
        key, sub = jax.random.split(key)
        lg = logits if cur is None else lg_step
        nxt = jax.random.categorical(sub, lg / args.temperature, axis=-1).astype(jnp.int32)
        nxt = jnp.clip(nxt, 0, cfg.vocab_size - 1)
        out.append(np.asarray(nxt))
        lg_step, cache = dec(params, cache, nxt[:, None])
        cur = True
    jax.block_until_ready(lg_step)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out, 1)
    print(f"arch={cfg.name} (reduced)  batch={b}  prompt={tp}  generated={args.tokens}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {t_decode/args.tokens*1e3:.1f} ms/token "
          f"({b*args.tokens/t_decode:.1f} tok/s aggregate)")
    for row in gen[:2]:
        print("sample:", row[:16].tolist(), "...")


if __name__ == "__main__":
    main()

"""Cipher throughput: the boundary-crossing tax itself.

Not a paper table per se, but the primitive behind Fig. 9's encryption
overhead: ChaCha20-CTR MB/s for the jnp path, the Pallas kernel (interpret),
and the host path.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.crypto import chacha, ctr
from repro.kernels.chacha20 import ops as kops


def _time(f, *args, reps=5):
    f(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run():
    kw = chacha.key_to_words(bytes(range(32)))
    nw = chacha.nonce_to_words(b"\x01" * 12)
    n_mb = 4
    x = jnp.zeros((n_mb * 1024 * 1024 // 4,), jnp.uint32)

    enc = jax.jit(lambda v: ctr.encrypt_array(v, kw, nw, 0))
    dt = _time(enc, x)
    rows = [("chacha20_jnp", dt * 1e6, f"{n_mb / dt:.1f}MB/s")]

    state0 = kops.make_state0(kw, nw, 0)
    pall = jax.jit(lambda v: kops.chacha20_xor_words(v, state0, impl="pallas", interpret=True))
    small = jnp.zeros((256 * 1024 // 4,), jnp.uint32)
    dtp = _time(pall, small, reps=2)
    rows.append(("chacha20_pallas_interpret", dtp * 1e6, f"{0.25 / dtp:.1f}MB/s"))

    data = b"\x00" * (n_mb * 1024 * 1024)
    t0 = time.perf_counter()
    chacha.chacha20_encrypt_bytes(bytes(range(32)), b"\x01" * 12, 0, data)
    dth = time.perf_counter() - t0
    rows.append(("chacha20_host_numpy", dth * 1e6, f"{n_mb / dth:.1f}MB/s"))
    return rows

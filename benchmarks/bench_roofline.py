"""§Roofline emitter: per-cell terms from the dry-run report (reads
reports/dryrun.json; run the dry-run first)."""

from __future__ import annotations

import json
import os


def run():
    path = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun.json")
    if not os.path.exists(path):
        return [("roofline_report", 0.0, "missing:run_repro.launch.dryrun_first")]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") != "OK":
            continue
        rf = r["roofline"]
        rows.append(
            (f"roofline_{key.replace('|', '_')}", rf["dominant" ] == "compute" and rf["compute_s"] * 1e6 or 0.0,
             f"dom={rf['dominant']},c={rf['compute_s']:.3e}s,"
             f"m={rf['memory_s']:.3e}s,x={rf['collective_s']:.3e}s")
        )
    return rows

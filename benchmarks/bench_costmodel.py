"""Calibrated cost model vs reality: per-workload prediction error.

The tentpole's honesty check (`repro/perf/`): calibrate THIS machine's
probes in-process, trace each smoke workload (k-means / sample sort / grep)
through `perf.model.trace_workload`, predict its steady-state per-round
time and compile time from the probe constants — then run the real thing
and report `pred_error = |predicted - measured| / measured` per
(workload, keystream impl) cell. Acceptance (CI bench-smoke lane):
every cell's steady-state pred_error <= 0.5.

Also measured here:

  * sim consistency — `AdmissionSim` virtual time on a single-job trace
    must equal the closed-form compile + dispatch + rounds x round_delay
    computed from the SAME calibrated TimingModel (the sim and the model
    read the same probes; if they drift, hillclimb cell K ranks fiction);
  * auto vs default knob vector — the kmeans runner is built twice, once
    with every knob resolved under the ACTIVE model and once with the
    model forced off (the historical defaults), both steady states
    measured. The model-driven vector must match or beat the hand-set
    one (<= 1.15x, or be literally the same vector).

All cells run on a 1-device in-process mesh: the model prices launches,
blocks, and wire bytes read off the traced program, so the single-device
numbers are the per-shard quantities the calibration probes measured on
the same mesh shape. (Cross-device wire timing is `bench_shuffle`'s job.)

Machine-readable output: `run()` fills the module-level `LAST_METRICS`
dict, which `benchmarks/run.py` serializes to BENCH_costmodel.json
(schema in `benchmarks/README.md`).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core.driver import make_iterative_runner
from repro.core.grep import make_grep_spec
from repro.core.kmeans import generate_points, make_kmeans_iterative_spec
from repro.core.shuffle import SecureShuffleConfig
from repro.core.sort import make_sample_sort_spec
from repro.crypto import chacha
from repro.perf.calibrate import run_calibration
from repro.perf.model import CostModel, clear_active_model, set_active_model, trace_workload

# Filled by run(); serialized by benchmarks/run.py into BENCH_costmodel.json.
LAST_METRICS: dict = {}

IMPLS = ("pallas-interpret", "jnp")
PRED_ERROR_MAX = 0.5  # CI acceptance: every steady-state cell within 50%
ROUNDS = 8  # fused rounds per dispatch: amortizes per-dispatch overhead


def _cfg(impl: str = "auto", coalesce=None) -> SecureShuffleConfig:
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x09" * 12),
        impl=impl, coalesce=coalesce,
    )


def _workloads(n: int):
    """(name, spec, inputs, state, items_per_round) for the three smoke
    workloads, shaped for a 1-device mesh and `ROUNDS` fused rounds per
    dispatch. `items_per_round` is what each round's map_fn touches —
    grep's streaming map slices one chunk per round, not the whole input."""
    k = 8
    pts, _ = generate_points(n, k, seed=9)
    kmeans = ("kmeans", make_kmeans_iterative_spec(k, 1, n_rounds=ROUNDS),
              {"p": jnp.asarray(pts), "w": jnp.ones((n,), jnp.float32)},
              jnp.asarray(pts[:k]), n)

    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    edges = jnp.asarray([-10.0, 10.0], jnp.float32)
    sort = ("sort", make_sample_sort_spec(1, n, n_rounds=ROUNDS),
            {"v": vals},
            {"edges": edges,
             "sorted": jnp.full((1, n), jnp.inf, jnp.float32),
             "counts": jnp.zeros((1,), jnp.float32)}, n)

    patterns = jnp.asarray([2, 3, 5, 7], jnp.int32)
    tokens = jnp.asarray(rng.integers(0, 11, size=(n,)), jnp.int32)
    grep_spec = dataclasses.replace(
        make_grep_spec(patterns, n // ROUNDS), n_rounds=ROUNDS)
    grep = ("grep", grep_spec, {"t": tokens},
            {"hits": jnp.zeros((patterns.shape[0],), jnp.float32),
             "cursor": jnp.uint32(0)}, n // ROUNDS)
    return [kmeans, sort, grep]


def _measure(runner, inputs, state, reps: int):
    """(compile+first-run seconds, best steady us/round over `reps`)."""
    t0 = time.perf_counter()
    jax.block_until_ready(runner(inputs, state, 0))
    compile_s = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(runner(inputs, state, 0))
        best = min(best, time.perf_counter() - t0)
    return compile_s, best * 1e6 / ROUNDS


def _measure_interleaved(cells, reps: int):
    """Per-cell best steady us/round, trials INTERLEAVED across cells.

    Sequential per-cell phases drift with machine load (bench_shuffle's
    lesson: +-60% on shared CI boxes); round-robin trials see the same
    conditions, so the per-cell minima are comparable to each other and
    to the calibration probes that ran moments earlier.
    """
    best = [float("inf")] * len(cells)
    for _ in range(reps):
        for i, (runner, inputs, state) in enumerate(cells):
            t0 = time.perf_counter()
            jax.block_until_ready(runner(inputs, state, 0))
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 / ROUNDS for b in best]


def run(smoke: bool = False):
    global LAST_METRICS
    rows = []
    n = 512 if smoke else 2048
    reps = 9  # trials are ~ms each; min-of-9 tames shared-box load spikes
    mesh = make_mesh((1,), ("data",))

    # calibrate HERE, on the machine being predicted — the whole point
    cal = run_calibration(mesh, quick=smoke)
    model = CostModel(cal)
    metrics: dict = {"smoke": smoke, "n": n, "rounds_per_dispatch": ROUNDS,
                     "calibration": cal.to_dict(), "pred_error": {}}
    # serialized by run.py even when an acceptance assert below fires —
    # the uploaded artifact is the diagnostic for a red bench-smoke lane
    LAST_METRICS = metrics

    try:
        cells = []
        for name, spec, inputs, state, items in _workloads(n):
            for impl in IMPLS:
                runner = make_iterative_runner(spec, mesh, secure=_cfg(impl))
                trace = trace_workload(runner, inputs, state,
                                       n_shards=1, n_local_items=items)
                t0 = time.perf_counter()
                jax.block_until_ready(runner(inputs, state, 0))
                compile_s = time.perf_counter() - t0
                cells.append({"key": f"{name}|{impl}", "impl": impl,
                              "runner": runner, "inputs": inputs,
                              "state": state, "trace": trace,
                              "compile_s": compile_s})
        measured = _measure_interleaved(
            [(c["runner"], c["inputs"], c["state"]) for c in cells], reps)

        worst = 0.0
        for c, meas_us in zip(cells, measured):
            trace = c["trace"]
            pred_us = model.predict_round_us(trace, impl=c["impl"])
            pred_compile = model.predict_compile_s(trace, impl=c["impl"])
            err = abs(pred_us - meas_us) / max(meas_us, 1e-9)
            worst = max(worst, err)
            metrics["pred_error"][c["key"]] = {
                "predicted_us_per_round": pred_us,
                "measured_us_per_round": meas_us,
                "pred_error": err,
                "predicted_compile_s": pred_compile,
                "measured_compile_s": c["compile_s"],
                "wire_bytes_per_round": trace.wire_bytes,
                "keystream_blocks_per_round": trace.keystream_blocks,
                "n_eqns": trace.n_eqns,
            }
            rows.append((f"costmodel_{c['key'].replace('|', '_')}", meas_us,
                         f"pred={pred_us:.0f}us;err={err:.2f};"
                         f"compile={c['compile_s']:.1f}s"))
        metrics["pred_error_max"] = worst
        # assert AFTER every cell is recorded: a red lane still uploads the
        # full pred_error table, not just the cells before the first miss
        bad = {k: v for k, v in metrics["pred_error"].items()
               if v["pred_error"] > PRED_ERROR_MAX}
        assert not bad, (
            "steady-state prediction off by more than "
            f"{PRED_ERROR_MAX:.0%} on: " + "; ".join(
                f"{k}: predicted {v['predicted_us_per_round']:.0f}us, "
                f"measured {v['measured_us_per_round']:.0f}us "
                f"(err {v['pred_error']:.0%})" for k, v in sorted(bad.items())))

        # --- sim virtual time vs the same TimingModel, closed form ----------
        from repro.runtime.sim import AdmissionSim, SimJob
        from repro.serve.service import bucket_for

        tm = model.timing_model()
        sim = AdmissionSim(tm, n_shards=1, min_chunk=ROUNDS, max_chunk=ROUNDS)
        got = sim.run([SimJob(0.0, n, ROUNDS)], "bucketed")["makespan_s"]
        n_pad = bucket_for(n, multiple=1, growth=2.0)
        want = (tm.xla_compile_s + tm.dispatch_s
                + ROUNDS * tm.round_delay(n_pad))
        assert abs(got - want) <= 1e-9 + 1e-6 * want, (got, want)
        metrics["sim_consistency"] = {"sim_makespan_s": got,
                                      "closed_form_s": want}
        rows.append(("costmodel_sim_consistency", 0.0,
                     f"sim={got:.3f}s;closed_form={want:.3f}s"))

        # --- model-driven auto knobs vs the hand-set defaults ---------------
        from repro.core.driver import resolve_halt_loop
        from repro.core.shuffle import resolve_chacha_impl, resolve_coalesce

        name, spec, inputs, state, _ = _workloads(n)[0]  # kmeans
        vectors = {}
        for label, active in (("default", None), ("auto", model)):
            set_active_model(active)
            impl, interpret = resolve_chacha_impl(None)
            vec = {"chacha_impl": impl, "interpret": interpret,
                   "coalesce": resolve_coalesce(None),
                   "loop_impl": resolve_halt_loop(None)}
            runner = make_iterative_runner(spec, mesh, secure=_cfg("auto"))
            _, meas_us = _measure(runner, inputs, state, reps)
            vectors[label] = {"vector": vec, "measured_us_per_round": meas_us}
        same = vectors["auto"]["vector"] == vectors["default"]["vector"]
        ratio = (vectors["auto"]["measured_us_per_round"]
                 / max(vectors["default"]["measured_us_per_round"], 1e-9))
        metrics["knob_vectors"] = {**vectors, "auto_matches_default": same,
                                   "auto_over_default": ratio}
        rows.append(("costmodel_auto_knobs",
                     vectors["auto"]["measured_us_per_round"],
                     f"default={vectors['default']['measured_us_per_round']:.0f}us;"
                     f"same_vector={same};ratio={ratio:.2f}"))
        assert same or ratio <= 1.15, (
            f"model-driven knob vector {vectors['auto']['vector']} is "
            f"{ratio:.2f}x the default's steady state", vectors)
    finally:
        clear_active_model()  # never leak an active model into other modules

    return rows

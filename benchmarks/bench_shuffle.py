"""Coalesced single-wire vs per-leaf secure shuffle: the boundary-crossing tax.

The paper's security argument lives at the mapper→reducer boundary; this
benchmark measures what one secure round PAYS to cross it under the two wire
layouts (`core/shuffle.py`):

  * structural counts — all_to_all collectives and keystream launches per
    secure round for the ≥3-leaf k-means tree through the fused driver,
    proven two independent ways: jaxpr inspection (`repro.tools.jaxprs`)
    and the shuffle's trace-time wire accounting. Coalesced must trace
    exactly 1 collective + 2 launches per round vs n_leaves and 2·n_leaves
    on the per-leaf path (asserted);
  * bytes per round — payload vs on-the-wire bytes (the coalesced layout's
    only overhead is the ≤15-word/leaf block-alignment pad), per-leaf
    breakdown included so zero CTR expansion stays auditable leaf by leaf;
  * steady-state per-round time — an isolated secure shuffle (encrypt →
    all_to_all → decrypt under shard_map) timed for coalesced vs per-leaf
    × keystream impls (pallas-interpret / jnp) on an 8-forced-host-device
    mesh in a SUBPROCESS (device-count forcing must precede jax init —
    same pattern as tests/conftest.run_in_subprocess). The 8-way mesh is
    the honest harness: the shuffle is a COLLECTIVE path, and on a 1-device
    in-process mesh the timing measures XLA's thread-pool parallelism
    across per-leaf fusions instead of the wire (the per-leaf path's 3
    independent keystreams fan out over idle cores there, an artifact no
    real mesh reproduces — every device is busy with its own shard).
    Coalesced must not be slower than per-leaf (asserted, min-of-reps;
    measured ~1.7x faster on pallas-interpret and ~4x on jnp, with ~3x
    faster secure compiles).

Machine-readable output: `run()` fills the module-level `LAST_METRICS`
dict, which `benchmarks/run.py` serializes to BENCH_shuffle.json (uploaded
by the CI bench-smoke lane alongside BENCH_driver.json).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.driver import make_iterative_runner
from repro.core.kmeans import generate_points, make_kmeans_iterative_spec
from repro.core.shuffle import SecureShuffleConfig, record_wire_bytes
from repro.crypto import chacha
from repro.tools.jaxprs import count_primitives

# Filled by run(); serialized by benchmarks/run.py into BENCH_shuffle.json.
LAST_METRICS: dict = {}

IMPLS = ("pallas-interpret", "jnp")

_TIMING_CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.compat import make_mesh
from repro.core.shuffle import SecureShuffleConfig, keyed_all_to_all
from repro.crypto import chacha

n_dev, c, d, reps, impls = {n_dev}, {c}, {d}, {reps}, {impls}
mesh = make_mesh((n_dev,), ("data",))
rng = np.random.default_rng(0)
tree = {{"k": jnp.asarray(rng.integers(0, 100, (n_dev * n_dev, c)), jnp.int32),
        "v": {{"s": jnp.asarray(rng.normal(size=(n_dev * n_dev, c, d)).astype(np.float32)),
              "c": jnp.asarray(rng.normal(size=(n_dev * n_dev, c)).astype(np.float32))}}}}
specs = compat.tree_map(lambda _: P("data"), tree)
out = {{}}
for impl in impls:
    out[impl] = {{}}
    fns = {{}}
    for coalesce, label in ((True, "coalesced"), (False, "per_leaf")):
        sec = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                                  nonce_words=chacha.nonce_to_words(b"\\x06" * 12),
                                  impl=impl, coalesce=coalesce)
        body = lambda t, sec=sec: keyed_all_to_all(t, "data", sec,
                                                   round_index=jnp.uint32(3))
        fn = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                      out_specs=specs, check_vma=False))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(tree))
        out[impl][label] = {{"compile_s": time.perf_counter() - t0}}
        fns[label] = fn
    # INTERLEAVED trials: time both layouts back-to-back under the same
    # machine conditions (sequential phases drift by +-60% on shared CI
    # boxes and would swamp the ~1.3x layout difference), min over all
    best = {{label: float("inf") for label in fns}}
    for _ in range(3):
        for label, fn in fns.items():
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(tree))
                best[label] = min(best[label], time.perf_counter() - t0)
    for label in fns:
        out[impl][label]["us_per_round"] = best[label] * 1e6
print(json.dumps(out))
"""


def _cfg(impl: str, coalesce) -> SecureShuffleConfig:
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x06" * 12),
        impl=impl, coalesce=coalesce,
    )


def _timing_subprocess(n_dev: int, c: int, d: int, reps: int, timeout: int) -> dict:
    """Run the timing section on `n_dev` forced host devices (fresh jax)."""
    code = textwrap.dedent(_TIMING_CHILD).format(
        n_dev=n_dev, c=c, d=d, reps=reps, impls=repr(tuple(IMPLS)))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"timing child failed:\n{p.stderr[-3000:]}"
    return json.loads(p.stdout.strip().splitlines()[-1])


def run(smoke: bool = False):
    global LAST_METRICS
    rows = []
    metrics: dict = {"smoke": smoke, "kmeans_tree": {}, "micro_shuffle": {}}
    mesh = make_mesh((1,), ("data",))

    # --- structural counts: the 3-leaf k-means tree through the driver -------
    # One fused secure round of the paper's workload shuffles the tree
    # {k: (R,C) i32, v: {s: (R,C,d) f32, c: (R,C) f32}} — 3 leaves. The scan
    # body traces once, so whole-program jaxpr counts ARE per-round counts.
    n, k = (512 if smoke else 2048), 8
    pts, _ = generate_points(n, k, seed=6)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((n,), jnp.float32)}
    spec = make_kmeans_iterative_spec(k, 1, n_rounds=2)
    c0 = jnp.asarray(pts[:k])
    for coalesce, label in ((True, "coalesced"), (False, "per_leaf")):
        runner = make_iterative_runner(spec, mesh,
                                       secure=_cfg("pallas-interpret", coalesce))
        with record_wire_bytes() as recs:
            jaxpr = jax.make_jaxpr(runner.abstract_fn)(inputs, c0, jnp.uint32(0))
        a2a = count_primitives(jaxpr, "all_to_all")
        launches = count_primitives(jaxpr, "pallas_call")
        (rec,) = [r for r in recs if r["secure"]]
        assert a2a == rec["collectives"] and launches == rec["keystream_launches"], (
            "jaxpr and wire accounting disagree", a2a, launches, rec)
        metrics["kmeans_tree"][label] = {
            "n_leaves": rec["leaves"],
            "all_to_all_per_round": a2a,
            "keystream_launches_per_round": launches,
            "bytes_per_round": rec["bytes"],
            "wire_bytes_per_round": rec["wire_bytes"],
            "pad_bytes_per_round": rec["pad_bytes"],
            "per_leaf_bytes": rec["per_leaf"],
        }
        rows.append((f"shuffle_round_{label}", 0.0,
                     f"all_to_all={a2a};keystream_launches={launches};"
                     f"wire_bytes={rec['wire_bytes']}"))
    co, pl = metrics["kmeans_tree"]["coalesced"], metrics["kmeans_tree"]["per_leaf"]
    assert co["n_leaves"] >= 3
    assert co["all_to_all_per_round"] == 1 and co["keystream_launches_per_round"] == 2, co
    assert pl["all_to_all_per_round"] == pl["n_leaves"], pl
    assert pl["keystream_launches_per_round"] == 2 * pl["n_leaves"], pl
    # zero CTR ciphertext expansion, leaf by leaf, on both layouts
    assert co["per_leaf_bytes"] == pl["per_leaf_bytes"]
    assert co["bytes_per_round"] == pl["bytes_per_round"]

    # --- steady-state per-round time: isolated secure shuffle, 8-dev mesh ----
    # The same 3-leaf tree shape on 8 forced host devices in a subprocess
    # (module docstring: why 1-device in-process timing would be a lie).
    n_dev = 8
    c, d = (64, 4) if smoke else (128, 8)
    reps = 5 if smoke else 10
    timing = _timing_subprocess(n_dev, c, d, reps, timeout=1800)
    metrics["micro_shuffle"] = {"n_dev": n_dev, "c": c, "d": d, "reps": reps,
                                **timing}
    for impl in IMPLS:
        per = timing[impl]
        speedup = per["per_leaf"]["us_per_round"] / max(
            per["coalesced"]["us_per_round"], 1e-9)
        per["speedup"] = speedup
        rows.append((f"shuffle_secure_round_{impl}_coalesced",
                     per["coalesced"]["us_per_round"],
                     f"speedup={speedup:.2f}x;"
                     f"compile={per['coalesced']['compile_s']:.1f}s"))
        rows.append((f"shuffle_secure_round_{impl}_per_leaf",
                     per["per_leaf"]["us_per_round"],
                     f"oracle;compile={per['per_leaf']['compile_s']:.1f}s"))
        assert per["coalesced"]["us_per_round"] <= per["per_leaf"]["us_per_round"], (
            f"coalesced secure round must not be slower than per-leaf on "
            f"{impl}: {per['coalesced']['us_per_round']:.1f}us vs "
            f"{per['per_leaf']['us_per_round']:.1f}us")

    LAST_METRICS = metrics
    return rows

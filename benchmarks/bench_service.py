"""Persistent job service: cold vs warm submit latency, hit rate, throughput.

The serving claim (`repro.serve.service`): once a size bucket's programs are
compiled, every further job admitted into that bucket runs WITHOUT tracing or
compiling anything — submit latency drops from the XLA-compile regime
(tens of seconds on the secure path) to the steady dispatch regime
(milliseconds). This benchmark measures exactly that, on a real service over
a forced multi-host-device mesh in a SUBPROCESS (device-count forcing must
precede jax init; same pattern as `bench_sharded_state`):

  * COLD job — first k-means submit into an empty cache: latency includes
    every chunk program compile (runner-cache misses > 0);
  * WARM jobs — same-bucket resubmits with different data and a DIFFERENT
    real size (padding reuses the bucket): per-job runner-cache misses must
    be 0 and the cache's XLA compile-cache size must not grow (zero new
    compiles — asserted), with warm latency >= 10x below cold (asserted);
  * THROUGHPUT at queue depths 1 / 4 / 16 — warm jobs submitted together,
    measuring end-to-end jobs/s as the admission queue deepens;
  * ADMISSION SIM — `runtime/sim.py::AdmissionSim` virtual makespans for
    the bucketed-cache policy vs compile-per-job on burst and straggler
    traces (no devices; the policy argument for the cache in one number).

Machine-readable output: `run()` fills the module-level `LAST_METRICS`
dict, which `benchmarks/run.py` serializes to BENCH_service.json (schema
documented there; uploaded by the CI bench-smoke lane).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

# Filled by run(); serialized by benchmarks/run.py into BENCH_service.json.
LAST_METRICS: dict = {}

_MARKER = "===BENCH_SERVICE_JSON==="

_SERVICE_CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.shuffle import SecureShuffleConfig
from repro.serve.service import SecureJobService

n_dev, n_items, depths, max_rounds, max_chunk = {n_dev}, {n_items}, {depths}, {max_rounds}, {max_chunk}
mesh = make_mesh((n_dev,), ("data",))
secure = SecureShuffleConfig(
    key_words=jnp.arange(8, dtype=jnp.uint32),
    nonce_words=jnp.zeros((3,), jnp.uint32))
svc = SecureJobService(mesh, secure=secure, max_concurrent=max(depths),
                       min_chunk=1, max_chunk=max_chunk)

def points(n, seed):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.1, 0.9, size=(4, 2))
    return (c[rng.integers(0, 4, size=n)]
            + rng.normal(scale=0.05, size=(n, 2))).astype(np.float32)

out = {{}}
# COLD: empty cache — latency includes every chunk-program compile
h = svc.submit_kmeans(points(n_items, seed=0), 4, max_rounds=max_rounds)
h.result(1800)
out["cold"] = {{"latency_s": h.latency_s, "runner_misses": h.runner_misses,
               "n_iter": h.result()["n_iter"]}}
assert h.runner_misses > 0, "cold job must build runners"

# WARM: different data AND different real size, same geometric bucket —
# the submit must skip tracing entirely (zero new compiles, zero misses)
compiles_before = svc.cache.compile_cache_size()
h2 = svc.submit_kmeans(points(max(4, n_items - n_dev), seed=1), 4,
                       max_rounds=max_rounds)
h2.result(1800)
new_compiles = svc.cache.compile_cache_size() - compiles_before
assert h2.runner_misses == 0, f"warm job missed the cache: {{h2.runner_misses}}"
assert new_compiles == 0, f"warm job compiled {{new_compiles}} programs"
assert h2.latency_s * 10.0 <= h.latency_s, (
    f"warm submit latency {{h2.latency_s:.4f}}s not >= 10x below cold "
    f"{{h.latency_s:.4f}}s")
out["warm"] = {{"latency_s": h2.latency_s, "runner_misses": h2.runner_misses,
               "new_compiles": new_compiles}}
out["speedup_cold_over_warm"] = h.latency_s / max(h2.latency_s, 1e-9)

# THROUGHPUT vs queue depth, warm cache: depth jobs submitted together
out["throughput"] = {{}}
for depth in depths:
    t0 = time.perf_counter()
    handles = [svc.submit_kmeans(points(n_items, seed=10 + i), 4,
                                 max_rounds=max_rounds)
               for i in range(depth)]
    for hh in handles:
        hh.result(1800)
    dt = time.perf_counter() - t0
    assert all(hh.runner_misses == 0 for hh in handles)
    out["throughput"][str(depth)] = {{"jobs": depth, "seconds": dt,
                                     "jobs_per_s": depth / dt}}

out["cache"] = svc.cache.stats()
stats = svc.stats()
out["jobs_completed"] = stats["jobs_completed"]
out["round_base"] = stats["round_base"]
svc.close()
print("{marker}")
print(json.dumps(out))
"""


def _run_child(n_dev: int, n_items: int, depths, max_rounds: int,
               max_chunk: int) -> dict:
    code = _SERVICE_CHILD.format(n_dev=n_dev, n_items=n_items,
                                 depths=list(depths), max_rounds=max_rounds,
                                 max_chunk=max_chunk, marker=_MARKER)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"service bench child failed:\n{proc.stderr[-4000:]}")
    payload = proc.stdout.split(_MARKER, 1)[1]
    return json.loads(payload)


def _run_sim(smoke: bool) -> dict:
    from repro.runtime.sim import AdmissionSim, burst_trace, straggler_trace

    sim = AdmissionSim()
    n_jobs = 8 if smoke else 16
    out = {}
    for name, trace in [("burst", burst_trace(n_jobs)),
                        ("straggler", straggler_trace(max(8, n_jobs - 4)))]:
        bucketed = sim.run(trace, "bucketed")
        per_job = sim.run(trace, "compile-per-job")
        out[name] = {
            "bucketed_makespan_s": bucketed["makespan_s"],
            "per_job_makespan_s": per_job["makespan_s"],
            "bucketed_compiles": bucketed["compiles"],
            "per_job_compiles": per_job["compiles"],
            "speedup": per_job["makespan_s"] / bucketed["makespan_s"],
        }
    return out


def run(smoke: bool = False):
    """Yields (name, us_per_call, derived) rows; fills LAST_METRICS."""
    n_dev = 4
    n_items = 64 if smoke else 256
    depths = (1, 4) if smoke else (1, 4, 16)
    max_rounds = 6 if smoke else 16
    max_chunk = 2 if smoke else 4

    metrics = _run_child(n_dev, n_items, depths, max_rounds, max_chunk)
    metrics["sim"] = _run_sim(smoke)
    LAST_METRICS.clear()
    LAST_METRICS.update(metrics)

    yield ("service_submit_cold", metrics["cold"]["latency_s"] * 1e6,
           f"misses={metrics['cold']['runner_misses']}")
    yield ("service_submit_warm", metrics["warm"]["latency_s"] * 1e6,
           f"speedup={metrics['speedup_cold_over_warm']:.0f}x "
           f"new_compiles={metrics['warm']['new_compiles']}")
    cache = metrics["cache"]
    hit_rate = cache["hits"] / max(1, cache["hits"] + cache["misses"])
    yield ("service_cache", 0.0,
           f"hits={cache['hits']} misses={cache['misses']} "
           f"hit_rate={hit_rate:.2f} resident={cache['resident']}")
    for depth, row in sorted(metrics["throughput"].items(), key=lambda kv: int(kv[0])):
        yield (f"service_throughput_depth{depth}",
               row["seconds"] / max(1, row["jobs"]) * 1e6,
               f"{row['jobs_per_s']:.1f} jobs/s")
    for trace, row in metrics["sim"].items():
        yield (f"service_sim_{trace}", 0.0,
               f"bucketed {row['bucketed_makespan_s']:.0f}s vs per-job "
               f"{row['per_job_makespan_s']:.0f}s ({row['speedup']:.1f}x)")

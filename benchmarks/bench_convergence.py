"""Paper Figs. 5-6: k-means convergence behaviour.

Reproduces: (a) convergence under the zero-threshold criterion takes many
iterations (paper: 76/90); (b) the diag/1000 threshold stops much earlier
(paper: 41st/21st) with little further centroid movement.
"""

from __future__ import annotations

import time

import jax

from repro.compat import make_mesh
from repro.core.kmeans import generate_points, kmeans_fit


def run():
    mesh = make_mesh((1,), ("data",))
    pts, _ = generate_points(20000, 10, seed=0, spread=0.08)

    t0 = time.perf_counter()
    res_thresh = kmeans_fit(pts, 10, mesh, max_iter=200)  # paper's diag/1000
    t_thresh = time.perf_counter() - t0

    res_zero = kmeans_fit(pts, 10, mesh, threshold=1e-7, max_iter=200)

    rows = [
        ("kmeans_convergence_diag1000",
         t_thresh / max(res_thresh.n_iter, 1) * 1e6,
         f"iters={res_thresh.n_iter}"),
        ("kmeans_convergence_zero_thresh", 0.0, f"iters={res_zero.n_iter}"),
        ("kmeans_threshold_speedup", 0.0,
         f"{res_zero.n_iter / max(res_thresh.n_iter, 1):.2f}x_fewer_iters"),
        ("kmeans_final_shift", 0.0, f"{res_thresh.center_shift[-1]:.2e}"),
    ]
    return rows

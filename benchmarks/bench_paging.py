"""Paper Fig. 8: cache-miss (EPC paging) rates vs input size.

The SecurePager reproduces the mechanism: once the reducer working set
exceeds the trusted budget, every sweep pays encrypt-on-evict /
verify-on-fetch for the overflow. We report paged bytes per k-means
iteration for growing n — the analogue of pidstat cache-miss rates, with the
n=1M two-orders-of-magnitude jump.
"""

from __future__ import annotations

from repro.core.paging import SecurePager


def run():
    rows = []
    budget = 1 << 20  # 1 MiB trusted budget (scaled-down EPC)
    point_bytes = 24  # json-ish [x, y] pair
    for n in (1000, 10000, 100000, 1000000):
        pager = SecurePager(budget_bytes=budget, key=b"\x31" * 32)
        page = 4096
        n_pages = max(1, n * point_bytes // page)
        for i in range(n_pages):
            pager.store(f"p{i}", b"\x00" * page)
        # one reduce sweep: reload all pages (paper: reduce is memory-heavy)
        for i in range(n_pages):
            pager.load(f"p{i}")
        paged = pager.stats.bytes_encrypted + pager.stats.bytes_decrypted
        rows.append(
            (f"paging_n{n}", pager.stats.modeled_seconds * 1e6,
             f"paged_bytes={paged},working_set={n_pages * page}")
        )
    return rows

"""Paper Fig. 9: encryption and SGX(enclave) overhead — the 4-combo sweep.

Two measurements:
  (a) cluster-level (virtual time, the paper's setting): k-means jobs under
      {enclave on/off} x {encryption on/off}; overheads computed exactly as
      the paper does — encryption overhead averaged across enclave settings,
      enclave overhead averaged across encryption settings.
  (b) device-level (real wall time): one secure-engine iteration with and
      without ChaCha20 on the shuffle, on CPU.

Paper's claims to compare against: encryption ~5%, enclave ~30% within EPC,
>200% once paging starts.

Section (c) measures the two secure-shuffle keystream backends head to head
(`core/shuffle.py` impl selection): XLA compile time of the first dispatch
and steady-state time per iteration, for the Pallas rows kernel vs the
vmapped jnp oracle. The jnp path's compile cost is the constant-folded
20-round ChaCha the Pallas fast path exists to avoid — the win is measured
here, not asserted.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core.kmeans import generate_points, make_kmeans_step
from repro.core.shuffle import SecureShuffleConfig
from repro.crypto import chacha
from repro.runtime.jobs import make_cluster, run_kmeans
from repro.runtime.node import SecurityPolicy
from repro.runtime.sim import TimingModel


def _cluster_time(pts, *, enclave: bool, encryption: bool, epc_budget: int):
    timing = TimingModel(epc_budget_bytes=epc_budget)
    cluster, client, _ = make_cluster(
        8, policy=SecurityPolicy(encryption=encryption, enclave=enclave), timing=timing
    )
    _, hist = run_kmeans(cluster, client, pts, 5, n_mappers=4, n_reducers=2, max_iter=2,
                         threshold=0.0)
    return float(np.mean([h["elapsed"] for h in hist]))


def run():
    rows = []
    pts, _ = generate_points(240, 5, seed=2)

    # over_epc: a 4 KiB trusted budget forces evict/verify on nearly every
    # touch — the paging-storm regime of the paper's n=1M point
    for label, budget in (("fits_epc", 32 << 20), ("over_epc", 4 << 10)):
        t = {}
        for enc in (False, True):
            for encl in (False, True):
                t[(encl, enc)] = _cluster_time(pts, enclave=encl, encryption=enc,
                                               epc_budget=budget)
        # paper's method: average the pairwise ratios
        enc_ovh = 0.5 * (
            (t[(False, True)] / t[(False, False)] - 1)
            + (t[(True, True)] / t[(True, False)] - 1)
        )
        encl_ovh = 0.5 * (
            (t[(True, False)] / t[(False, False)] - 1)
            + (t[(True, True)] / t[(False, True)] - 1)
        )
        rows.append((f"overhead_encryption_{label}", t[(True, True)] * 1e6,
                     f"{enc_ovh * 100:.1f}%"))
        rows.append((f"overhead_enclave_{label}", t[(True, True)] * 1e6,
                     f"{encl_ovh * 100:.1f}%"))

    # (b) device-level real wall time: secure vs plain shuffle
    mesh = make_mesh((1,), ("data",))
    pts2, _ = generate_points(50000, 10, seed=3)
    pts2 = jnp.asarray(pts2)
    w = jnp.ones((pts2.shape[0],), jnp.float32)
    sec = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\x09" * 12))
    times = {}
    for name, cfg in (("plain", None), ("secure", sec)):
        step = make_kmeans_step(mesh, secure=cfg)
        c = pts2[:10]
        c, _ = step(pts2, w, c)
        c, _ = step(pts2, w, c)  # 2nd warmup: committed-sharding recompile
        jax.block_until_ready(c)
        t0 = time.perf_counter()
        for _ in range(5):
            c, _ = step(pts2, w, c)
        jax.block_until_ready(c)
        times[name] = (time.perf_counter() - t0) / 5
    ovh = times["secure"] / times["plain"] - 1
    rows.append(("overhead_device_encryption", times["secure"] * 1e6,
                 f"{ovh * 100:.1f}%"))

    # (c) keystream impl sweep: compile time + steady-state, pallas vs jnp
    for impl in ("pallas", "jnp"):
        step = make_kmeans_step(mesh, secure=sec, chacha_impl=impl)
        c = pts2[:10]
        t0 = time.perf_counter()
        c, _ = step(pts2, w, c)
        jax.block_until_ready(c)
        compile_s = time.perf_counter() - t0  # first dispatch: compile + run
        c, _ = step(pts2, w, c)  # committed-sharding recompile
        jax.block_until_ready(c)
        t0 = time.perf_counter()
        for _ in range(5):
            c, _ = step(pts2, w, c)
        jax.block_until_ready(c)
        steady = (time.perf_counter() - t0) / 5
        rows.append((f"secure_chacha_{impl}", steady * 1e6,
                     f"compile={compile_s:.1f}s"))
    return rows

"""Sharded vs replicated carried state: per-device bytes + collective counts.

The driver's two-tier carried-state contract (`core/driver.py`) lets a large
per-reducer leaf — the sampling sort's (R, R·capacity) sorted table — stay
`P(axis)`-resident across rounds instead of being re-replicated by an
all_gather every round. This benchmark measures exactly what that buys on
the paper's sort workload, two independent ways:

  * structural counts — collective primitives per fused sort round, sharded
    vs replicated, by jaxpr inspection (`repro.tools.jaxprs
    .collective_counts`). Sharded must trace exactly ONE all_to_all (the
    shuffle) and exactly one FEWER all_gather (the table gather is gone)
    with zero other collectives added or removed (asserted, secure and
    plaintext);
  * per-device state bytes — the carried state actually resident on one
    device of an 8-forced-host-device mesh in a SUBPROCESS (device-count
    forcing must precede jax init; same pattern as `bench_shuffle`),
    measured off the final state's `addressable_shards`. The sharded table
    keeps one (1, R·capacity) row per device vs the full (R, R·capacity)
    replica — the dominant leaf shrinks ~Rx, and the total must shrink ≥4x
    on the 8-way mesh (asserted). The gathered outputs must be
    bit-identical across layouts (asserted).

Machine-readable output: `run()` fills the module-level `LAST_METRICS`
dict, which `benchmarks/run.py` serializes to BENCH_sharded_state.json
(uploaded by the CI bench-smoke lane alongside the other BENCH artifacts).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.driver import make_iterative_runner
from repro.core.shuffle import SecureShuffleConfig
from repro.core.sort import make_sample_sort_spec
from repro.crypto import chacha
from repro.tools.jaxprs import collective_counts

# Filled by run(); serialized by benchmarks/run.py into BENCH_sharded_state.json.
LAST_METRICS: dict = {}

_STATE_CHILD = """
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.driver import run_until
from repro.core.sort import make_sample_sort_spec

n_dev, capacity, n_rounds = {n_dev}, {capacity}, {n_rounds}
n = n_dev * capacity
mesh = make_mesh((n_dev,), ("data",))
rng = np.random.default_rng(0)
v = jnp.asarray((rng.exponential(scale=0.15, size=n) % 1.0).astype(np.float32))
edges = jnp.asarray(np.linspace(0.0, 1.001, n_dev + 1), jnp.float32)
out = {{}}
for sharded in (False, True):
    spec = make_sample_sort_spec(n_dev, capacity, halt_total=n,
                                 shard_state=sharded)
    init = {{"edges": edges,
            "sorted": jnp.full((n_dev, n_dev * capacity), jnp.inf, jnp.float32),
            "counts": jnp.zeros((n_dev,), jnp.float32)}}
    res = run_until(spec, {{"v": v}}, init, mesh, max_rounds=n_rounds,
                    warn_on_overflow=False)
    # bytes of carried state RESIDENT on device 0: a replicated leaf
    # contributes its full size, a P(axis) leaf only its local shard
    per_leaf = {{k: l.addressable_shards[0].data.nbytes
                for k, l in res.state.items()}}
    out[str(sharded)] = {{
        "per_device_state_bytes": sum(per_leaf.values()),
        "per_leaf_device_bytes": per_leaf,
        "global_state_bytes": sum(l.nbytes for l in jax.tree.leaves(res.state)),
        "rounds_executed": res.rounds_executed,
        "halted": bool(res.halted),
        "sorted": np.asarray(res.state["sorted"]).tolist(),
        "counts": np.asarray(res.state["counts"]).tolist(),
    }}
print(json.dumps(out))
"""


def _cfg() -> SecureShuffleConfig:
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x09" * 12),
        impl="pallas-interpret",
    )


def _sort_round_counts(shard_state: bool, secure) -> dict:
    """Collective counts of one traced fused sort chunk (1-axis mesh)."""
    mesh = make_mesh((1,), ("data",))
    r, n = 1, 64
    spec = make_sample_sort_spec(r, n, halt_total=n, shard_state=shard_state)
    runner = make_iterative_runner(spec, mesh, secure=secure)
    inputs = {"v": jnp.zeros((n,), jnp.float32)}
    state = {
        "edges": jnp.zeros((r + 1,), jnp.float32),
        "sorted": jnp.full((r, r * n), jnp.inf, jnp.float32),
        "counts": jnp.zeros((r,), jnp.float32),
    }
    jaxpr = jax.make_jaxpr(runner.abstract_fn)(inputs, state, jnp.uint32(0))
    return collective_counts(jaxpr)


def _state_subprocess(n_dev: int, capacity: int, n_rounds: int, timeout: int) -> dict:
    """Run the per-device-bytes section on `n_dev` forced host devices."""
    code = textwrap.dedent(_STATE_CHILD).format(
        n_dev=n_dev, capacity=capacity, n_rounds=n_rounds)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"state child failed:\n{p.stderr[-3000:]}"
    return json.loads(p.stdout.strip().splitlines()[-1])


def run(smoke: bool = False):
    global LAST_METRICS
    rows = []
    metrics: dict = {"smoke": smoke, "sort_round_collectives": {},
                     "per_device_state": {}}

    # --- structural counts: the fused sort round, sharded vs replicated ------
    for secure, sec_label in ((None, "plaintext"), (_cfg(), "secure")):
        sharded = _sort_round_counts(True, secure)
        replicated = _sort_round_counts(False, secure)
        metrics["sort_round_collectives"][sec_label] = {
            "sharded": sharded, "replicated": replicated}
        assert sharded["all_to_all"] == replicated["all_to_all"] == 1, (
            sec_label, sharded, replicated)
        assert replicated["all_gather"] == sharded["all_gather"] + 1, (
            sec_label, sharded, replicated)
        assert all(sharded[k] == replicated[k]
                   for k in sharded if k != "all_gather"), (sharded, replicated)
        rows.append((f"sort_round_collectives_{sec_label}", 0.0,
                     f"all_to_all={sharded['all_to_all']};"
                     f"all_gather={sharded['all_gather']}(sharded)"
                     f"vs{replicated['all_gather']}(replicated)"))

    # --- per-device carried-state bytes on a real 8-way mesh -----------------
    n_dev = 8
    capacity = 64 if smoke else 256
    state = _state_subprocess(n_dev, capacity, n_rounds=3, timeout=1800)
    rep, sh = state["False"], state["True"]
    # identical results is the precondition that makes the bytes comparable
    assert sh["sorted"] == rep["sorted"] and sh["counts"] == rep["counts"], (
        "sharded and replicated sort state diverged")
    assert sh["rounds_executed"] == rep["rounds_executed"]
    ratio = rep["per_device_state_bytes"] / max(sh["per_device_state_bytes"], 1)
    for side in (rep, sh):  # the gathered values are not trajectory metrics
        side.pop("sorted"), side.pop("counts")
    metrics["per_device_state"] = {
        "n_dev": n_dev, "capacity": capacity,
        "replicated": rep, "sharded": sh, "ratio": ratio,
    }
    # the (R, R*capacity) table dominates: per-device state must shrink >=4x
    # on the 8-way mesh (the table itself shrinks ~8x; edges/counts stay tiny)
    assert ratio >= 4.0, (
        f"sharded state must be >=4x smaller per device on {n_dev} devices, "
        f"got {ratio:.2f}x ({rep['per_device_state_bytes']} -> "
        f"{sh['per_device_state_bytes']} bytes)")
    rows.append(("sort_state_bytes_per_device_replicated", 0.0,
                 f"bytes={rep['per_device_state_bytes']}"))
    rows.append(("sort_state_bytes_per_device_sharded", 0.0,
                 f"bytes={sh['per_device_state_bytes']};ratio={ratio:.2f}x"))

    LAST_METRICS = metrics
    return rows

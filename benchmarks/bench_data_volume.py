"""Paper Table II: data volume exchanged per MapReduce step (split/shuffle/
output), measured from the SCBR router's wire accounting on real jobs.

The second section counts per-round shuffle bytes through the ITERATIVE
driver (`core/driver.py`): `core/shuffle.py`'s trace-time wire accounting
records exactly what crosses the all_to_all per fused round — raw leaf bytes
in plaintext mode, packed u32 wire words in secure mode — and asserts the
two are EQUAL: ChaCha20-CTR is a stream cipher, so ciphertext expansion on
the shuffle wire is zero (the paper's lightweight-encryption claim in bytes,
not just time).
"""

from __future__ import annotations

import json

import numpy as np

import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.driver import run_iterative_mapreduce
from repro.core.kmeans import generate_points, make_kmeans_iterative_spec
from repro.core.shuffle import SecureShuffleConfig, record_wire_bytes
from repro.crypto import chacha
from repro.pubsub import protocol as pr
from repro.runtime.jobs import make_cluster, run_kmeans


def run():
    rows = []
    for n in (1000, 4000, 8000):
        pts, _ = generate_points(n, 10, seed=4)
        cluster, client, _ = make_cluster(8)
        volumes = {"split": 0, "shuffle": 0, "output": 0}
        orig = cluster.router.publish
        hdr_key = client.session.header

        def spy(msg, _orig=orig, _vol=volumes):
            t = msg.open_header(hdr_key)["type"]
            if t == pr.MAP_DATATYPE:
                _vol["split"] += msg.wire_bytes
            elif t == pr.REDUCE_DATATYPE:
                _vol["shuffle"] += msg.wire_bytes
            elif t == pr.RESULT:
                _vol["output"] += msg.wire_bytes
            return _orig(msg)

        cluster.router.publish = spy
        _, hist = run_kmeans(cluster, client, pts, 10, n_mappers=4, n_reducers=2,
                             max_iter=2, threshold=0.0)
        iters = max(len(hist), 1)
        rows.append(
            (f"data_volume_n{n}", 0.0,
             f"split={volumes['split'] // iters}B,"
             f"shuffle={volumes['shuffle'] // iters}B,"
             f"output={volumes['output'] // iters}B")
        )

    # --- per-round shuffle bytes through the iterative driver ----------------
    # A shuffle inside the driver's lax.scan traces ONCE, so each run below
    # records a single per-round byte count (fixed shapes => every round
    # moves the same volume). Secure mode is measured under BOTH wire
    # layouts: the coalesced single-wire default and the per-leaf oracle —
    # the per-leaf byte breakdown in each record proves zero CTR ciphertext
    # expansion LEAF BY LEAF even after coalescing. The packed wire carries
    # ZERO pad bytes (leaf tails share keystream blocks; core/shuffle.py),
    # so wire_bytes == payload bytes on every path, and the plaintext run
    # (default coalesced) rides the same single-collective topology.
    mesh = make_mesh((1,), ("data",))
    n, k, n_rounds = 2048, 8, 2
    pts, _ = generate_points(n, k, seed=6)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((n,), jnp.float32)}
    spec = make_kmeans_iterative_spec(k, 1, n_rounds=n_rounds)
    c0 = jnp.asarray(pts[:k])
    sec = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\x0b" * 12))
    with record_wire_bytes() as recs:
        run_iterative_mapreduce(spec, inputs, c0, mesh)
        run_iterative_mapreduce(spec, inputs, c0, mesh, secure=sec,
                                coalesce=True)
        run_iterative_mapreduce(spec, inputs, c0, mesh, secure=sec,
                                coalesce=False)
    plain = [r for r in recs if not r["secure"]]
    secure = [r for r in recs if r["secure"]]
    assert len(plain) == 1 and len(secure) == 2, recs
    coalesced = [r for r in secure if r["coalesced"]]
    per_leaf = [r for r in secure if not r["coalesced"]]
    assert len(coalesced) == 1 and len(per_leaf) == 1, recs
    for rec in secure:
        assert rec["bytes"] == plain[0]["bytes"], (
            f"CTR must not expand the shuffle wire: secure={rec['bytes']}B "
            f"plain={plain[0]['bytes']}B (coalesced={rec['coalesced']})"
        )
        # leaf-by-leaf: every leaf's payload equals its plaintext bytes
        assert rec["per_leaf"] == plain[0]["per_leaf"], (rec, plain[0])
    assert coalesced[0]["collectives"] == 1, coalesced
    assert per_leaf[0]["collectives"] == per_leaf[0]["leaves"], per_leaf
    # packed wire: zero pad bytes travel, plaintext shares the 1-collective
    # topology (kmeans leaves are word-aligned, so plain bytes == packed)
    assert coalesced[0]["pad_bytes"] == 0, coalesced
    assert plain[0]["coalesced"] and plain[0]["collectives"] == 1, plain
    rows.append((
        "driver_shuffle_bytes_per_round", 0.0,
        f"plain={plain[0]['bytes']}B,secure={coalesced[0]['bytes']}B,"
        f"rounds={n_rounds},expansion=0B,"
        f"coalesce_pad={coalesced[0]['pad_bytes']}B,"
        f"per_leaf={','.join(str(b) for b in coalesced[0]['per_leaf'])}",
    ))
    return rows

"""Paper Table II: data volume exchanged per MapReduce step (split/shuffle/
output), measured from the SCBR router's wire accounting on real jobs."""

from __future__ import annotations

import json

import numpy as np

from repro.core.kmeans import generate_points
from repro.pubsub import protocol as pr
from repro.runtime.jobs import make_cluster, run_kmeans


def run():
    rows = []
    for n in (1000, 4000, 8000):
        pts, _ = generate_points(n, 10, seed=4)
        cluster, client, _ = make_cluster(8)
        volumes = {"split": 0, "shuffle": 0, "output": 0}
        orig = cluster.router.publish
        hdr_key = client.session.header

        def spy(msg, _orig=orig, _vol=volumes):
            t = msg.open_header(hdr_key)["type"]
            if t == pr.MAP_DATATYPE:
                _vol["split"] += msg.wire_bytes
            elif t == pr.REDUCE_DATATYPE:
                _vol["shuffle"] += msg.wire_bytes
            elif t == pr.RESULT:
                _vol["output"] += msg.wire_bytes
            return _orig(msg)

        cluster.router.publish = spy
        _, hist = run_kmeans(cluster, client, pts, 10, n_mappers=4, n_reducers=2,
                             max_iter=2, threshold=0.0)
        iters = max(len(hist), 1)
        rows.append(
            (f"data_volume_n{n}", 0.0,
             f"split={volumes['split'] // iters}B,"
             f"shuffle={volumes['shuffle'] // iters}B,"
             f"output={volumes['output'] // iters}B")
        )
    return rows

"""Paper Fig. 7: average time per k-means iteration vs input size,
plus fused-driver vs per-round dispatch accounting.

Paper observation: completion time is dominated by n (observations), mildly
inflected by k; the n=1M point shows super-linear growth from cache misses.
We sweep n at CPU-feasible sizes and report us/iteration (secure engine,
encryption on).

The fused section runs the same converged k-means job twice:
  * per-round   — one host dispatch per iteration (`make_kmeans_step` loop,
                  the historical execution model);
  * fused       — `rounds_per_dispatch` iterations per dispatch through
                  `run_iterative_mapreduce` (`lax.scan` under shard_map).
It reports us/iteration for both and the host round-trip counts; the fused
driver must dispatch >= 2x fewer times per converged run.

The final section sweeps the secure-shuffle keystream backends
(`core/shuffle.py` impl selection) through the fused driver: compile time of
the first dispatch and steady-state us/iteration for the Pallas rows kernel
vs the vmapped jnp oracle, so the Pallas fast path's compile+runtime win is
measured on the exact hot path the ROADMAP names.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.kmeans import generate_points, kmeans_fit, make_kmeans_runner, make_kmeans_step
from repro.core.shuffle import SecureShuffleConfig
from repro.crypto import chacha


def _cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x05" * 12),
    )


def _per_round_converged(pts, k, mesh, threshold, max_iter=64):
    """Historical loop: one dispatch per iteration. Returns (n_iter, secs)."""
    step = make_kmeans_step(mesh, secure=_cfg())
    n = pts.shape[0]
    w = jnp.ones((n,), jnp.float32)
    centers = pts[:k]
    # warmup compile (and the committed-sharding recompile)
    c, _ = step(pts, w, centers)
    c, _ = step(pts, w, c)
    jax.block_until_ready(c)

    centers = pts[:k]
    t0 = time.perf_counter()
    it = 0
    for it in range(1, max_iter + 1):
        centers, shift = step(pts, w, centers)
        if float(shift) < threshold:  # host inspects every round: 1 dispatch/iter
            break
    jax.block_until_ready(centers)
    return it, time.perf_counter() - t0


def run():
    mesh = make_mesh((1,), ("data",))
    rows = []
    for n in (1000, 10000, 100000):
        for k in (10, 50):
            pts, _ = generate_points(n, k, seed=1)
            pts = jnp.asarray(pts)
            w = jnp.ones((n,), jnp.float32)
            centers = pts[:k]
            step = make_kmeans_step(mesh, secure=_cfg())
            # two warmup calls: the 2nd recompiles for committed-sharding args
            centers, _ = step(pts, w, centers)
            centers, _ = step(pts, w, centers)
            jax.block_until_ready(centers)
            iters = 5
            t0 = time.perf_counter()
            for _ in range(iters):
                centers, shift = step(pts, w, centers)
            jax.block_until_ready(centers)
            dt = (time.perf_counter() - t0) / iters
            rows.append((f"kmeans_iter_n{n}_k{k}", dt * 1e6, f"n={n},k={k}"))

    # --- fused driver vs per-round loop: dispatches per converged run --------
    n, k, rounds = 4000, 8, 4
    pts, _ = generate_points(n, k, seed=2, spread=0.03)
    pts = jnp.asarray(pts)
    lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)
    threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0  # paper §V rule

    loop_iters, loop_secs = _per_round_converged(pts, k, mesh, threshold)

    # prebuild the runner so the warmup fit below actually warms the jit
    # cache the timed fit uses (a fresh runner would recompile from scratch)
    runner = make_kmeans_runner(mesh, k, secure=_cfg(), rounds_per_dispatch=rounds)
    kmeans_fit(pts, k, mesh, secure=_cfg(), threshold=threshold, runner=runner)
    t0 = time.perf_counter()
    res = kmeans_fit(pts, k, mesh, secure=_cfg(), threshold=threshold, runner=runner)
    fused_secs = time.perf_counter() - t0

    ratio = loop_iters / max(res.n_dispatches, 1)
    rows.append((
        "kmeans_converged_per_round", loop_secs / max(loop_iters, 1) * 1e6,
        f"dispatches={loop_iters}",
    ))
    rows.append((
        "kmeans_converged_fused", fused_secs / max(res.n_iter, 1) * 1e6,
        f"dispatches={res.n_dispatches};iters={res.n_iter};"
        f"dispatch_reduction={ratio:.1f}x",
    ))
    assert ratio >= 2.0, (
        f"fused driver must cut host round-trips >=2x, got {ratio:.2f}x "
        f"({loop_iters} vs {res.n_dispatches})"
    )

    # --- keystream impl sweep on the fused driver: compile + steady state ----
    w = jnp.ones((n,), jnp.float32)
    inputs = {"p": pts, "w": w}
    c0 = pts[:k]
    for impl in ("pallas", "jnp"):
        runner, per_dispatch = make_kmeans_runner(
            mesh, k, secure=_cfg(), rounds_per_dispatch=rounds, chacha_impl=impl)
        t0 = time.perf_counter()
        c, _, _ = runner(inputs, c0, 0)
        jax.block_until_ready(c)
        compile_s = time.perf_counter() - t0  # first dispatch: compile + run
        c, _, _ = runner(inputs, c, per_dispatch)
        jax.block_until_ready(c)
        reps, offset = 3, 2 * per_dispatch
        t0 = time.perf_counter()
        for i in range(reps):
            c, _, _ = runner(inputs, c, offset + i * per_dispatch)
        jax.block_until_ready(c)
        per_iter = (time.perf_counter() - t0) / (reps * per_dispatch)
        rows.append((f"kmeans_fused_secure_{impl}", per_iter * 1e6,
                     f"compile={compile_s:.1f}s"))
    return rows

"""Paper Fig. 7: average time per k-means iteration vs input size,
plus fused-driver vs per-round dispatch accounting and the convergence-aware
early-exit section.

Paper observation: completion time is dominated by n (observations), mildly
inflected by k; the n=1M point shows super-linear growth from cache misses.
We sweep n at CPU-feasible sizes and report us/iteration (secure engine,
encryption on).

The fused section runs the same converged k-means job twice:
  * per-round   — one host dispatch per iteration (`make_kmeans_step` loop,
                  the historical execution model);
  * fused       — convergence-aware `run_until` through `kmeans_fit`:
                  adaptive chunks (min_chunk, x2 growth up to
                  rounds_per_dispatch) with the paper's §V threshold rule as
                  the ON-DEVICE halt_fn.
It reports us/iteration for both and the host round-trip counts; the fused
driver must dispatch >= 2x fewer times per converged run.

The convergence section audits the early exit itself on secure k-means:
  * rounds EXECUTED vs rounds DISPATCHED — strictly fewer executed when
    convergence precedes the chunk boundary (asserted);
  * wire bytes — `record_wire_bytes` on the halt-masked chunk shows the
    per-round shuffle volume for live rounds and ZERO bytes for the masked
    no-op branch (asserted), so halted rounds are attributed 0 bytes;
  * fused early-exit results bit-identical to the per-round reference loop
    stopped by the same float32 threshold comparison (asserted);
  * `loop_impl` shoot-out — 'while' (lax.while_loop) vs 'masked_scan'
    (lax.cond-gated scan): compile + steady-state timings for both.
    Measured on CPU with the pallas-interpret keystream, 'while' compiles
    ~2x faster (34s vs 67s: the cond duplicates the round body into an
    extra branch) and runs ~13% faster per executed round (it exits instead
    of paying the masked no-op tail) — hence it is `DEFAULT_HALT_LOOP`.
    'masked_scan' is the documented LOSER, kept because its traced skip
    branch is what makes the zero-bytes-for-halted-rounds claim auditable
    and its aux layout matches the plain scan.

The final section sweeps the secure-shuffle keystream backends
(`core/shuffle.py` impl selection) through the fused driver: compile time of
the first dispatch and steady-state us/iteration for the Pallas rows kernel
vs the vmapped jnp oracle, so the Pallas fast path's compile+runtime win is
measured on the exact hot path the ROADMAP names.

Machine-readable output: `run(...)` fills the module-level `LAST_METRICS`
dict (compile/steady-state per impl, rounds executed vs dispatched, wire
bytes) which `benchmarks/run.py` serializes to BENCH_driver.json so the perf
trajectory is tracked across PRs.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.driver import HALT_LOOP_IMPLS, run_until
from repro.core.kmeans import (
    generate_points,
    kmeans_fit,
    make_kmeans_iterative_spec,
    make_kmeans_runner,
    make_kmeans_step,
)
from repro.core.shuffle import SecureShuffleConfig, record_wire_bytes
from repro.crypto import chacha

# Filled by run(); serialized by benchmarks/run.py into BENCH_driver.json.
LAST_METRICS: dict = {}


def _cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x05" * 12),
    )


def _per_round_converged(pts, k, mesh, threshold, max_iter=64):
    """Historical loop: one dispatch per iteration. Returns
    (n_iter, secs, centers) — the float32 threshold comparison matches the
    on-device halt_fn bit-for-bit, so the stop round is the reference for
    the fused path's early exit."""
    step = make_kmeans_step(mesh, secure=_cfg())
    n = pts.shape[0]
    w = jnp.ones((n,), jnp.float32)
    centers = pts[:k]
    # warmup compile (and the committed-sharding recompile)
    c, _ = step(pts, w, centers)
    c, _ = step(pts, w, c)
    jax.block_until_ready(c)

    centers = pts[:k]
    t0 = time.perf_counter()
    it = 0
    for it in range(1, max_iter + 1):
        centers, shift = step(pts, w, centers)
        # host inspects every round: 1 dispatch/iter; f32 compare == device
        if np.float32(shift) < np.float32(threshold):
            break
    jax.block_until_ready(centers)
    return it, time.perf_counter() - t0, centers


def run(smoke: bool = False):
    global LAST_METRICS
    metrics: dict = {"smoke": smoke, "impls": {}, "convergence": {},
                     "halt_loop_impls": {}}
    mesh = make_mesh((1,), ("data",))
    rows = []
    if not smoke:
        for n in (1000, 10000, 100000):
            for k in (10, 50):
                pts, _ = generate_points(n, k, seed=1)
                pts = jnp.asarray(pts)
                w = jnp.ones((n,), jnp.float32)
                centers = pts[:k]
                step = make_kmeans_step(mesh, secure=_cfg())
                # two warmup calls: the 2nd recompiles for committed-sharding args
                centers, _ = step(pts, w, centers)
                centers, _ = step(pts, w, centers)
                jax.block_until_ready(centers)
                iters = 5
                t0 = time.perf_counter()
                for _ in range(iters):
                    centers, shift = step(pts, w, centers)
                jax.block_until_ready(centers)
                dt = (time.perf_counter() - t0) / iters
                rows.append((f"kmeans_iter_n{n}_k{k}", dt * 1e6, f"n={n},k={k}"))

    # --- fused driver vs per-round loop: dispatches per converged run --------
    n, k, rounds = (2000, 8, 8) if smoke else (4000, 8, 8)
    pts, _ = generate_points(n, k, seed=2, spread=0.03)
    pts = jnp.asarray(pts)
    lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)
    threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0  # paper §V rule

    loop_iters, loop_secs, loop_centers = _per_round_converged(pts, k, mesh, threshold)

    # prebuild the runner cache so the warmup fit below actually warms the
    # jit caches the timed fit uses (a fresh cache would recompile everything)
    cache = make_kmeans_runner(mesh, k, secure=_cfg(), rounds_per_dispatch=rounds,
                               threshold=threshold, min_chunk=2)
    kmeans_fit(pts, k, mesh, runner=cache, max_iter=64)
    t0 = time.perf_counter()
    res = kmeans_fit(pts, k, mesh, runner=cache, max_iter=64)
    fused_secs = time.perf_counter() - t0

    ratio = loop_iters / max(res.n_dispatches, 1)
    rows.append((
        "kmeans_converged_per_round", loop_secs / max(loop_iters, 1) * 1e6,
        f"dispatches={loop_iters}",
    ))
    rows.append((
        "kmeans_converged_fused", fused_secs / max(res.n_iter, 1) * 1e6,
        f"dispatches={res.n_dispatches};iters={res.n_iter};"
        f"dispatch_reduction={ratio:.1f}x",
    ))
    assert ratio >= 2.0, (
        f"fused driver must cut host round-trips >=2x, got {ratio:.2f}x "
        f"({loop_iters} vs {res.n_dispatches})"
    )

    # --- convergence-aware early exit: executed vs dispatched, wire bytes ----
    # fused early-exit must stop at the reference loop's round, bit-identical
    assert res.n_iter == loop_iters, (res.n_iter, loop_iters)
    np.testing.assert_array_equal(np.asarray(res.centers), np.asarray(loop_centers))
    assert res.n_iter < res.n_rounds_dispatched, (
        f"convergence (round {res.n_iter}) preceded the chunk boundary, so "
        f"executed rounds must be strictly fewer than dispatched "
        f"({res.n_rounds_dispatched})"
    )

    # wire-byte audit on one halt-masked chunk: trace a FRESH runner (jit
    # caches would skip tracing) and attribute bytes per round
    spec = make_kmeans_iterative_spec(k, 1, threshold=threshold)
    inputs = {"p": pts, "w": jnp.ones((n,), jnp.float32)}
    c0 = pts[:k]
    with record_wire_bytes() as recs:
        audit = run_until(spec, inputs, c0, mesh, secure=_cfg(),
                          max_rounds=rounds, min_chunk=rounds,
                          loop_impl="masked_scan")
    live = [r for r in recs if not r["halted"]]
    halted = [r for r in recs if r["halted"]]
    assert len(live) == 1, recs  # the scan traces one live round
    assert halted and all(r["bytes"] == 0 for r in halted), (
        f"halted rounds must be attributed zero shuffle wire bytes: {recs}")
    per_round_bytes = live[0]["bytes"]
    halted_rounds = audit.rounds_dispatched - audit.rounds_executed
    rows.append((
        "kmeans_run_until_secure", 0.0,
        f"rounds_executed={audit.rounds_executed};"
        f"rounds_dispatched={audit.rounds_dispatched};"
        f"wire_bytes_executed={per_round_bytes * audit.rounds_executed};"
        f"wire_bytes_halted={0 * halted_rounds}",
    ))
    assert audit.halted and audit.rounds_executed < audit.rounds_dispatched
    metrics["convergence"] = {
        "n": n, "k": k, "threshold": threshold,
        "loop_iters": loop_iters,
        "rounds_executed": int(audit.rounds_executed),
        "rounds_dispatched": int(audit.rounds_dispatched),
        "n_dispatches_adaptive": int(res.n_dispatches),
        "rounds_dispatched_adaptive": int(res.n_rounds_dispatched),
        "wire_bytes_per_executed_round": int(per_round_bytes),
        "wire_bytes_executed_total": int(per_round_bytes * audit.rounds_executed),
        "wire_bytes_halted_rounds": 0,
        "dispatch_reduction_vs_per_round": ratio,
    }

    # --- halt-loop shoot-out: masked_scan (lax.cond) vs while (lax.while) ----
    for loop_impl in HALT_LOOP_IMPLS:
        runners: dict = {}
        t0 = time.perf_counter()
        first = run_until(spec, inputs, c0, mesh, secure=_cfg(), max_rounds=rounds,
                          min_chunk=rounds, loop_impl=loop_impl, runners=runners)
        compile_s = time.perf_counter() - t0  # first dispatch: compile + run
        np.testing.assert_array_equal(  # both loop shapes are bit-identical
            np.asarray(first.state), np.asarray(audit.state))
        reps = 1 if smoke else 3
        t0 = time.perf_counter()
        for _ in range(reps):
            out = run_until(spec, inputs, c0, mesh, secure=_cfg(), max_rounds=rounds,
                            min_chunk=rounds, loop_impl=loop_impl, runners=runners)
        steady_per_iter = (time.perf_counter() - t0) / (reps * out.rounds_executed)
        rows.append((f"kmeans_halt_loop_{loop_impl}", steady_per_iter * 1e6,
                     f"compile={compile_s:.1f}s;executed={out.rounds_executed}"))
        metrics["halt_loop_impls"][loop_impl] = {
            "compile_s": compile_s, "steady_us_per_iter": steady_per_iter * 1e6}

    # --- keystream impl sweep on the fused driver: compile + steady state ----
    impls = ("pallas",) if smoke else ("pallas", "jnp")
    w = jnp.ones((n,), jnp.float32)
    inputs = {"p": pts, "w": w}
    c0 = pts[:k]
    for impl in impls:
        icache = make_kmeans_runner(mesh, k, secure=_cfg(), rounds_per_dispatch=rounds,
                                    threshold=threshold, chacha_impl=impl)
        t0 = time.perf_counter()
        r1 = kmeans_fit(pts, k, mesh, runner=icache, max_iter=64)
        compile_s = time.perf_counter() - t0  # first fit: compiles + runs
        reps = 1 if smoke else 3
        t0 = time.perf_counter()
        for _ in range(reps):
            r2 = kmeans_fit(pts, k, mesh, runner=icache, max_iter=64)
        per_iter = (time.perf_counter() - t0) / (reps * max(r2.n_iter, 1))
        rows.append((f"kmeans_fused_secure_{impl}", per_iter * 1e6,
                     f"compile={compile_s:.1f}s"))
        metrics["impls"][impl] = {
            "compile_s": compile_s,
            "steady_us_per_iter": per_iter * 1e6,
            "rounds_executed": int(r2.n_iter),
            "rounds_dispatched": int(r2.n_rounds_dispatched),
        }
    LAST_METRICS = metrics
    return rows

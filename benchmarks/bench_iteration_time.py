"""Paper Fig. 7: average time per k-means iteration vs input size.

Paper observation: completion time is dominated by n (observations), mildly
inflected by k; the n=1M point shows super-linear growth from cache misses.
We sweep n at CPU-feasible sizes and report us/iteration (secure engine,
encryption on).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.kmeans import generate_points, make_kmeans_step
from repro.core.shuffle import SecureShuffleConfig
from repro.crypto import chacha


def _cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x05" * 12),
    )


def run():
    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    rows = []
    for n in (1000, 10000, 100000):
        for k in (10, 50):
            pts, _ = generate_points(n, k, seed=1)
            pts = jnp.asarray(pts)
            w = jnp.ones((n,), jnp.float32)
            centers = pts[:k]
            step = make_kmeans_step(mesh, secure=_cfg())
            # two warmup calls: the 2nd recompiles for committed-sharding args
            centers, _ = step(pts, w, centers)
            centers, _ = step(pts, w, centers)
            jax.block_until_ready(centers)
            iters = 5
            t0 = time.perf_counter()
            for _ in range(iters):
                centers, shift = step(pts, w, centers)
            jax.block_until_ready(centers)
            dt = (time.perf_counter() - t0) / iters
            rows.append((f"kmeans_iter_n{n}_k{k}", dt * 1e6, f"n={n},k={k}"))
    return rows

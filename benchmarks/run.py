"""Benchmark harness: one module per paper table/figure.

  bench_convergence     Figs. 5-6  k-means convergence + threshold rule
  bench_iteration_time  Fig. 7     time/iteration vs input size
  bench_paging          Fig. 8     EPC-paging (cache miss) cliff
  bench_overhead        Fig. 9     encryption x enclave 4-combo overheads
  bench_data_volume     Table II   split/shuffle/output bytes per iteration
  bench_tcb             Table I    trusted-code-base sizes (+ <30 LOC scripts)
  bench_crypto          cipher throughput (the boundary tax primitive)
  bench_roofline        §Roofline terms from the dry-run report

Prints ``name,us_per_call,derived`` CSV.
"""

import sys
import traceback

from benchmarks import (
    bench_convergence,
    bench_crypto,
    bench_data_volume,
    bench_iteration_time,
    bench_overhead,
    bench_paging,
    bench_roofline,
    bench_tcb,
)

MODULES = [
    bench_tcb,
    bench_crypto,
    bench_convergence,
    bench_iteration_time,
    bench_paging,
    bench_overhead,
    bench_data_volume,
    bench_roofline,
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{mod.__name__},NaN,ERROR:{type(e).__name__}:{e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

  bench_convergence     Figs. 5-6  k-means convergence + threshold rule
  bench_iteration_time  Fig. 7     time/iteration vs input size + early exit
  bench_paging          Fig. 8     EPC-paging (cache miss) cliff
  bench_overhead        Fig. 9     encryption x enclave 4-combo overheads
  bench_data_volume     Table II   split/shuffle/output bytes per iteration
  bench_tcb             Table I    trusted-code-base sizes (+ <30 LOC scripts)
  bench_crypto          cipher throughput (the boundary tax primitive)
  bench_shuffle         coalesced vs per-leaf secure shuffle wire
                        (collectives/launches/bytes/time per round)
  bench_sharded_state   sharded vs replicated carried state (per-device
                        state bytes + collective counts on the sort round)
  bench_service         persistent job service: cold vs warm submit latency,
                        runner-cache hit rate, throughput vs queue depth
  bench_costmodel       calibrated cost model vs reality: per-workload
                        steady-state prediction error, sim consistency,
                        auto vs default knob vectors
  bench_roofline        §Roofline terms from the dry-run report

Prints ``name,us_per_call,derived`` CSV.

Machine-readable perf trajectory: driver-path metrics (compile time,
steady-state per-iteration time per keystream impl, rounds executed vs
dispatched, shuffle wire bytes) are serialized to ``BENCH_driver.json`` —
modules publish them via a module-level ``LAST_METRICS`` dict — and the
secure-shuffle wire metrics (collectives + keystream launches per round,
bytes, coalesced vs per-leaf steady state; ``bench_shuffle``) additionally
to ``BENCH_shuffle.json``, and the carried-state layout metrics (per-device
state bytes + sort-round collective counts, sharded vs replicated;
``bench_sharded_state``) to ``BENCH_sharded_state.json``, and the
calibrated cost-model prediction errors (``bench_costmodel``) to
``BENCH_costmodel.json``. Every artifact's full field-by-field schema is
documented in ``benchmarks/README.md``. CI runs ``run.py --smoke``
(reduced sizes, driver-relevant modules only) and uploads the JSONs as
artifacts so regressions are visible across PRs; the smoke lane fails if
any cost-model ``pred_error`` cell exceeds 50%.

``BENCH_service.json`` schema (``bench_service``; all latencies in seconds):

  {schema, smoke, backend, platform, jax,    # shared envelope
   service: {
     cold:  {latency_s, runner_misses, n_iter},   # empty-cache submit
     warm:  {latency_s, runner_misses,            # same-bucket resubmit;
             new_compiles},                       # both must be 0
     speedup_cold_over_warm,                      # acceptance: >= 10
     throughput: {"<depth>": {jobs, seconds, jobs_per_s}, ...},
     cache: {hits, misses, evictions, resident,
             max_resident, compile_cache_size},   # RunnerCache.stats()
     jobs_completed, round_base,                  # service counters
     sim: {burst | straggler:                     # AdmissionSim policies
           {bucketed_makespan_s, per_job_makespan_s,
            bucketed_compiles, per_job_compiles, speedup}}}}
"""

import argparse
import inspect
import json
import platform
import sys
import traceback

import jax

from benchmarks import (
    bench_convergence,
    bench_costmodel,
    bench_crypto,
    bench_data_volume,
    bench_iteration_time,
    bench_overhead,
    bench_paging,
    bench_roofline,
    bench_service,
    bench_sharded_state,
    bench_shuffle,
    bench_tcb,
)

MODULES = [
    bench_tcb,
    bench_crypto,
    bench_convergence,
    bench_iteration_time,
    bench_shuffle,
    bench_sharded_state,
    bench_service,
    bench_costmodel,
    bench_paging,
    bench_overhead,
    bench_data_volume,
    bench_roofline,
]

# the modules exercised by the CI smoke lane: the driver + shuffle hot paths
SMOKE_MODULES = [bench_iteration_time, bench_shuffle, bench_sharded_state,
                 bench_service, bench_costmodel]

# envelope keys shared by every BENCH_*.json artifact
ENVELOPE = ("schema", "smoke", "backend", "platform", "jax")


def _warn_stale_sections(path: str, owned: set) -> None:
    """Warn when an existing artifact holds sections this run won't rewrite.

    Checked-in BENCH_*.json files outlive module renames; a section nobody
    owns any more (e.g. a leftover ``bench_oblivious``) would silently pin
    numbers from an old HEAD forever. The rewrite below drops it — this
    warning makes the drop visible in the CI log.
    """
    try:
        with open(path) as f:
            old = json.load(f)
    except (OSError, ValueError):
        return
    for key in old:
        if key not in owned and key not in ENVELOPE:
            print(f"WARNING: {path} section {key!r} is not produced by any "
                  f"current benchmark module; dropping it", file=sys.stderr)


def _run_module(mod, smoke: bool):
    """Call mod.run(), passing smoke= only when the module accepts it."""
    params = inspect.signature(mod.run).parameters
    if "smoke" in params:
        return mod.run(smoke=smoke)
    return mod.run()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes, driver-relevant modules only (CI lane)")
    ap.add_argument("--json-out", default="BENCH_driver.json",
                    help="path for the machine-readable driver metrics")
    ap.add_argument("--shuffle-json-out", default="BENCH_shuffle.json",
                    help="path for the machine-readable shuffle-wire metrics")
    ap.add_argument("--sharded-state-json-out", default="BENCH_sharded_state.json",
                    help="path for the machine-readable carried-state metrics")
    ap.add_argument("--service-json-out", default="BENCH_service.json",
                    help="path for the machine-readable job-service metrics "
                         "(schema in the module docstring above)")
    ap.add_argument("--costmodel-json-out", default="BENCH_costmodel.json",
                    help="path for the calibrated cost-model prediction-error "
                         "metrics (schema in benchmarks/README.md)")
    args = ap.parse_args(argv)

    modules = SMOKE_MODULES if args.smoke else MODULES
    print("name,us_per_call,derived")
    failures = 0
    metrics: dict = {
        "schema": 1,
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
    }
    for mod in modules:
        try:
            for name, us, derived in _run_module(mod, args.smoke):
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{mod.__name__},NaN,ERROR:{type(e).__name__}:{e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
        mod_metrics = getattr(mod, "LAST_METRICS", None)
        if mod_metrics:
            metrics[mod.__name__.removeprefix("benchmarks.")] = mod_metrics
    _warn_stale_sections(
        args.json_out,
        {m.__name__.removeprefix("benchmarks.") for m in MODULES})
    with open(args.json_out, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
    print(f"wrote {args.json_out}", file=sys.stderr)
    # the shuffle-wire trajectory gets its own artifact: the acceptance
    # numbers (collectives + keystream launches per secure round, bytes,
    # coalesced vs per-leaf timing) live here
    if bench_shuffle in modules:
        shuffle_metrics = {k: metrics[k] for k in ENVELOPE}
        shuffle_metrics["shuffle"] = getattr(bench_shuffle, "LAST_METRICS", {})
        with open(args.shuffle_json_out, "w") as f:
            json.dump(shuffle_metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.shuffle_json_out}", file=sys.stderr)
    # likewise for the carried-state layout trajectory: per-device state
    # bytes and sort-round collective counts, sharded vs replicated
    if bench_sharded_state in modules:
        state_metrics = {k: metrics[k] for k in ENVELOPE}
        state_metrics["sharded_state"] = getattr(
            bench_sharded_state, "LAST_METRICS", {})
        with open(args.sharded_state_json_out, "w") as f:
            json.dump(state_metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.sharded_state_json_out}", file=sys.stderr)
    # and the serving trajectory: cold/warm submit latency, runner-cache hit
    # rate, throughput vs queue depth, admission-sim policy makespans
    if bench_service in modules:
        service_metrics = {k: metrics[k] for k in ENVELOPE}
        service_metrics["service"] = getattr(bench_service, "LAST_METRICS", {})
        with open(args.service_json_out, "w") as f:
            json.dump(service_metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.service_json_out}", file=sys.stderr)
    # and the cost-model trajectory: per-(workload, impl) prediction error,
    # sim-vs-closed-form consistency, auto-vs-default knob vectors. The CI
    # bench-smoke lane fails when pred_error_max exceeds 0.5.
    if bench_costmodel in modules:
        cm_metrics = {k: metrics[k] for k in ENVELOPE}
        cm_metrics["costmodel"] = getattr(bench_costmodel, "LAST_METRICS", {})
        with open(args.costmodel_json_out, "w") as f:
            json.dump(cm_metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.costmodel_json_out}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

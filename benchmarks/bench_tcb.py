"""Paper Table I: size of the code loaded into enclaves.

The analogue of the paper's text/data/bss sections: bytes (and LOC) of the
trusted-side components — the cipher, the engine, the in-enclave interpreter
— versus the untrusted router, plus the user scripts (paper: word count in
<30 LOC)."""

from __future__ import annotations

import os

import repro

BASE = os.path.dirname(repro.__file__)

GROUPS = {
    "worker_enclave": ["crypto/chacha.py", "crypto/ctr.py", "crypto/mac.py",
                       "core/engine.py", "core/shuffle.py", "core/secvm.py",
                       "core/paging.py"],
    "scbr_enclave": ["pubsub/messages.py", "pubsub/router.py"],
    "client": ["runtime/node.py", "crypto/keys.py"],
    "kernels": ["kernels/chacha20/kernel.py", "kernels/kmeans/kernel.py"],
}


def _sizes(paths):
    total_b = total_loc = 0
    for p in paths:
        full = os.path.join(BASE, p)
        src = open(full).read()
        total_b += len(src.encode())
        total_loc += sum(
            1 for ln in src.splitlines() if ln.strip() and not ln.strip().startswith("#")
        )
    return total_b, total_loc


def run():
    rows = []
    for name, paths in GROUPS.items():
        b, loc = _sizes(paths)
        rows.append((f"tcb_{name}", 0.0, f"bytes={b},loc={loc}"))

    from repro.runtime.jobs import KMEANS_MAP, KMEANS_REDUCE, WORDCOUNT_MAP, WORDCOUNT_REDUCE

    for name, src in (("wordcount", WORDCOUNT_MAP + WORDCOUNT_REDUCE),
                      ("kmeans", KMEANS_MAP + KMEANS_REDUCE)):
        loc = sum(1 for ln in src.splitlines() if ln.strip() and not ln.strip().startswith("#"))
        rows.append((f"user_script_{name}", 0.0, f"loc={loc}"))
    return rows

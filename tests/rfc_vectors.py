"""RFC 8439 ChaCha20 test vectors, shared by the crypto and kernel suites."""

import numpy as np

RFC_KEY = bytes(range(32))  # 00 01 02 ... 1f
RFC_NONCE_232 = bytes.fromhex("000000090000004a00000000")
# §2.3.2 expected output state (serialized keystream words)
RFC_BLOCK_232 = np.array(
    [
        0xE4E7F110, 0x15593BD1, 0x1FDD0F50, 0xC47120A3,
        0xC7F4D1C7, 0x0368C033, 0x9AAA2204, 0x4E6CD4C3,
        0x466482D2, 0x09AA9F07, 0x05D7C214, 0xA2028BD9,
        0xD19C12B5, 0xB94E16DE, 0xE883D0CB, 0x4E3C50A2,
    ],
    dtype=np.uint32,
)

# §2.4.2 full encryption test
RFC_NONCE_242 = bytes.fromhex("000000000000004a00000000")
RFC_PLAINTEXT = (
    b"Ladies and Gentlemen of the class of '99: If I could offer you "
    b"only one tip for the future, sunscreen would be it."
)
RFC_CIPHERTEXT = bytes.fromhex(
    "6e2e359a2568f98041ba0728dd0d6981"
    "e97e7aec1d4360c20a27afccfd9fae0b"
    "f91b65c5524733ab8f593dabcd62b357"
    "1639d624e65152ab8f530c359f0861d8"
    "07ca0dbf500d6a6156a38e088a22b65e"
    "52bc514d16ccf806818ce91ab7793736"
    "5af90bbf74a35be6b40b8eedf2785e42"
    "874d"
)

"""Roofline analytics: param counts and MODEL_FLOPS sanity."""

import pytest

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.tools.roofline import model_flops, param_counts

# public park figures (B params); generous tolerance: we count our
# implementation (padded vocab, simplified blocks), not the HF checkpoint
EXPECT_TOTAL = {
    "mistral-large-123b": (110e9, 135e9),
    "deepseek-67b": (60e9, 75e9),
    "glm4-9b": (8e9, 11e9),
    # our stack uses gated SwiGLU (3 mats) everywhere; upstream granite-20b
    # has a 2-mat MLP -> our N is ~1.4x the checkpoint's 20B
    "granite-20b": (18e9, 29e9),
    "chameleon-34b": (30e9, 38e9),
    "rwkv6-1.6b": (1.3e9, 2.2e9),
    "zamba2-1.2b": (0.9e9, 1.8e9),
    "whisper-base": (0.05e9, 0.12e9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_positive_and_active_le_total(arch):
    cfg = get_config(arch)
    total, active = param_counts(cfg)
    assert 0 < active <= total
    if cfg.family != "moe":
        assert active == total


@pytest.mark.parametrize("arch,bounds", sorted(EXPECT_TOTAL.items()))
def test_param_counts_match_public_figures(arch, bounds):
    total, _ = param_counts(get_config(arch))
    lo, hi = bounds
    assert lo < total < hi, f"{arch}: {total/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("granite-moe-3b-a800m")
    total, active = param_counts(cfg)
    assert active < total  # 8 of 40 experts active
    assert active > total * 8 / 40 * 0.5


def test_model_flops_scaling():
    cfg = get_config("glm4-9b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dec = model_flops(cfg, get_shape("decode_32k"))
    assert tr == pytest.approx(3 * pf, rel=0.01)  # 6ND vs 2ND, same tokens
    assert dec < pf / 1000  # one token vs 32k tokens

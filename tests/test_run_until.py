"""Convergence-aware driver (`run_until`): halt semantics, masked no-op
rounds, keystream accounting across early-exited + resumed chunks, loop-impl
equivalence, adaptive chunking, overflow warnings, engine entry point."""

from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core import shuffle
from repro.core.driver import (
    HALT_LOOP_IMPLS,
    IterativeSpec,
    make_iterative_runner,
    run_iterative_mapreduce,
    run_until,
)
from repro.core.engine import MapReduceSpec, identity_hash, run_mapreduce_until
from repro.core.grep import grep_count
from repro.core.kmeans import (
    generate_points,
    kmeans_fit,
    make_kmeans_iterative_spec,
    make_kmeans_runner,
    make_kmeans_step,
)
from repro.core.shuffle import SecureShuffleConfig, record_wire_bytes
from repro.core.sort import sample_sort
from repro.crypto import chacha


def _mesh1():
    return make_mesh((1,), ("data",))


def _secure_cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x11" * 12),
        counter0=7,
    )


def _counting_spec(halt_at=None, n=8, capacity=8):
    """Each round shuffles n unit items into state += n; aux records the
    GLOBAL round index and the received count. halt_at: halt once the global
    round index reaches it (the halting round still executes)."""

    def map_fn(state, inputs, r):
        return jnp.zeros((n,), jnp.int32), {"v": jnp.ones((n,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        got = jax.lax.psum(jnp.sum(jnp.where(valid, rv["v"], 0.0)), "data")
        return state + got, {"round": r, "got": got}

    halt_fn = None
    if halt_at is not None:
        def halt_fn(state, aux, r):
            return r >= jnp.uint32(halt_at)

    return IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=capacity, n_rounds=1, halt_fn=halt_fn)


_INPUTS = {"x": jnp.zeros((4,), jnp.float32)}


# --- fused early exit == per-round reference loop -----------------------------


def test_run_until_kmeans_bitexact_vs_loop_stopped_same_round():
    """Fused `run_until` with the on-device threshold halt lands on the same
    round — and the same bits — as the per-round oracle loop stopped by the
    identical (float32) threshold comparison."""
    mesh = _mesh1()
    pts, _ = generate_points(1024, 6, seed=3, spread=0.04)
    pts = jnp.asarray(pts)
    lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)
    threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0

    step = make_kmeans_step(mesh)
    w = jnp.ones((pts.shape[0],), jnp.float32)
    c_loop = pts[:6]
    loop_shifts = []
    for it in range(1, 65):
        c_loop, s = step(pts, w, c_loop)
        loop_shifts.append(float(s))
        if np.float32(s) < np.float32(threshold):  # device compares in f32
            break

    res = kmeans_fit(pts, 6, mesh, threshold=threshold, max_iter=64)
    assert res.n_iter == it
    np.testing.assert_array_equal(np.asarray(res.centers), np.asarray(c_loop))
    assert res.center_shift == loop_shifts
    # convergence preceded the budget: strictly fewer dispatches than rounds
    assert res.n_dispatches < res.n_iter


@pytest.mark.parametrize("loop_impl", HALT_LOOP_IMPLS)
def test_loop_impls_bitexact(loop_impl):
    """'masked_scan' and 'while' produce identical outputs, counts, flags."""
    spec = replace(_counting_spec(halt_at=2), n_rounds=6)
    runner = make_iterative_runner(spec, _mesh1(), loop_impl=loop_impl)
    state, aux, dropped, n_exec, halted = runner(_INPUTS, jnp.float32(0.0))
    assert int(n_exec) == 3 and bool(halted)
    assert float(state) == 3 * 8
    np.testing.assert_array_equal(np.asarray(aux["round"]),
                                  np.array([0, 1, 2, 0, 0, 0], np.uint32))
    np.testing.assert_array_equal(np.asarray(aux["got"]),
                                  np.array([8, 8, 8, 0, 0, 0], np.float32))
    np.testing.assert_array_equal(np.asarray(dropped), np.zeros(6, np.int32))


def test_halt_on_round0_executes_exactly_one_round():
    """halt_fn True from the start still executes round 0 — exactly one
    round's shuffle — and the chunk's masked tail is a no-op."""
    spec = _counting_spec(halt_at=0)
    res = run_until(spec, _INPUTS, jnp.float32(0.0), _mesh1(),
                    max_rounds=8, min_chunk=4)
    assert res.rounds_executed == 1 and res.halted
    assert res.n_dispatches == 1 and res.rounds_dispatched == 4
    assert float(res.state) == 8.0  # exactly one round's worth arrived
    np.testing.assert_array_equal(res.aux["round"], np.array([0], np.uint32))
    assert res.dropped.shape == (1,)


def test_unhalted_spec_runs_budget_through_run_until():
    """A spec without halt_fn is legal: run_until executes every round."""
    spec = _counting_spec(halt_at=None)
    res = run_until(spec, _INPUTS, jnp.float32(0.0), _mesh1(), max_rounds=5)
    assert res.rounds_executed == res.rounds_dispatched == 5
    assert not res.halted
    np.testing.assert_array_equal(res.aux["round"], np.arange(5, dtype=np.uint32))


# --- keystream accounting across chunks ---------------------------------------


def test_early_exit_then_resume_keeps_round_indices_disjoint():
    """An early-exited chunk followed by a resumed chunk covers a gapless,
    duplicate-free global round range: the halted tail of chunk 1 consumed
    no round indices (hence no keystream), and chunk 2 starts exactly at
    rounds_executed."""
    first = run_until(_counting_spec(halt_at=2), _INPUTS, jnp.float32(0.0), _mesh1(),
                      max_rounds=8, min_chunk=8)
    assert first.rounds_executed == 3 and first.rounds_dispatched == 8
    second = run_until(_counting_spec(halt_at=5), _INPUTS, first.state, _mesh1(),
                       max_rounds=8, round_offset=first.rounds_executed)
    rounds = np.concatenate([first.aux["round"], second.aux["round"]])
    np.testing.assert_array_equal(rounds, np.arange(6, dtype=np.uint32))
    assert len(set(rounds.tolist())) == len(rounds)  # no counter reuse
    assert float(second.state) == 6 * 8


def test_executed_round_keystreams_disjoint_across_resumed_chunks():
    """The keystream blocks of the rounds EXECUTED by an early-exited chunk
    and its resumption never collide (two-time-pad check at the block level,
    on the exact global round indices run_until hands each chunk)."""
    cfg = _secure_cfg()
    n_rows, blocks = 4, 2
    n_words = blocks * 16
    ids = jnp.arange(n_rows, dtype=jnp.uint32)
    # chunk 1 executed global rounds 0..2, chunk 2 (offset 3) rounds 3..5
    seen = set()
    for rnd in (0, 1, 2, 3, 4, 5):
        ks = shuffle._keystream_rows(
            cfg, ids, ids, jnp.uint32(cfg.counter0), blocks, n_words, jnp.uint32(rnd))
        for row in np.asarray(ks):
            for block in row.reshape(-1, 16):
                key = block.tobytes()
                assert key not in seen, f"keystream block reused at round {rnd}"
                seen.add(key)
    assert len(seen) == 6 * n_rows * blocks


def test_halted_rounds_move_zero_wire_bytes():
    """Trace-time audit: the masked no-op branch records zero shuffle bytes
    (it contains no all_to_all and derives no keystream)."""
    spec = _counting_spec(halt_at=1)
    with record_wire_bytes() as recs:
        run_until(spec, _INPUTS, jnp.float32(0.0), _mesh1(),
                  max_rounds=4, min_chunk=4, loop_impl="masked_scan")
    live = [r for r in recs if not r["halted"]]
    halted = [r for r in recs if r["halted"]]
    assert len(live) == 1 and live[0]["bytes"] > 0  # scan traces one live round
    assert halted, "halt-masked loop must trace a passthrough branch"
    assert all(r["bytes"] == 0 for r in halted)


# --- adaptive chunking --------------------------------------------------------


def test_adaptive_chunks_grow_geometrically_and_cap():
    """Chunks go min_chunk, xgrowth, ... capped at max_chunk and clipped to
    the remaining budget; dispatched rounds follow."""
    spec = _counting_spec(halt_at=None)
    runners = {}
    res = run_until(spec, _INPUTS, jnp.float32(0.0), _mesh1(), max_rounds=11,
                    min_chunk=1, growth=2, max_chunk=4, runners=runners)
    # 1 + 2 + 4 + 4 = 11 rounds in 4 dispatches; no 8-round program compiled
    assert res.rounds_executed == res.rounds_dispatched == 11
    assert res.n_dispatches == 4
    assert sorted(runners) == [1, 2, 4]


def test_runner_cache_reused_across_fits():
    """A prebuilt kmeans runner cache serves multiple fits (shared jit)."""
    mesh = _mesh1()
    pts, _ = generate_points(512, 4, seed=2)
    pts = jnp.asarray(pts)
    lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)
    threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0
    cache = make_kmeans_runner(mesh, 4, threshold=threshold, rounds_per_dispatch=4)
    a = kmeans_fit(pts, 4, mesh, runner=cache, max_iter=32)
    sizes_after_first = sorted(cache.runners)
    b = kmeans_fit(pts, 4, mesh, runner=cache, max_iter=32)
    assert sizes_after_first and sorted(cache.runners) == sizes_after_first
    assert a.n_iter == b.n_iter
    np.testing.assert_array_equal(np.asarray(a.centers), np.asarray(b.centers))


def test_kmeans_runner_cache_without_threshold_rejected():
    mesh = _mesh1()
    pts, _ = generate_points(64, 2, seed=0)
    cache = make_kmeans_runner(mesh, 2, rounds_per_dispatch=2)  # no threshold
    with pytest.raises(ValueError, match="threshold"):
        kmeans_fit(pts, 2, mesh, runner=cache)


# --- overflow surfacing -------------------------------------------------------


def test_overflow_warning_names_round_and_capacity():
    n, capacity = 8, 4

    def map_fn(state, inputs, r):
        ks = jnp.arange(n, dtype=jnp.int32)
        # only round 1 emits all n items (into one bucket of capacity 4)
        valid = jnp.where(r == 1, jnp.ones_like(ks), (ks < capacity).astype(jnp.int32))
        return jnp.where(valid > 0, 0, -1), {"v": jnp.ones((n,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        return state, {"r": r}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=capacity, n_rounds=3)
    with pytest.warns(RuntimeWarning, match=r"round 1: n_dropped=4.*capacity 4"):
        run_iterative_mapreduce(spec, {"x": jnp.zeros((n,), jnp.float32)},
                                jnp.float32(0.0), _mesh1())


def test_overflow_warning_global_round_index_through_run_until():
    """run_until warnings carry the GLOBAL round index, offset included."""

    def map_fn(state, inputs, r):
        ks = jnp.arange(6, dtype=jnp.int32)
        keys = jnp.where(r == 12, jnp.zeros_like(ks), jnp.where(ks < 2, 0, -1))
        return keys, {"v": jnp.ones((6,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        return state, {"r": r}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=2, n_rounds=1)
    with pytest.warns(RuntimeWarning, match=r"round 12: n_dropped=4"):
        run_until(spec, {"x": jnp.zeros((6,), jnp.float32)}, jnp.float32(0.0),
                  _mesh1(), max_rounds=4, round_offset=10)


def test_overflow_warning_global_index_in_later_multiround_chunk():
    """Regression for chunk-relative indices: an overflow deep inside a
    LATER chunk must be reported by its GLOBAL round index. With min_chunk=2
    and growth=2, rounds split into chunks [0,1] and [2..5]; the overflow at
    global round 5 sits at chunk-relative index 3 of the second chunk, and
    the warning must say 'round 5', never 'round 3'."""

    def map_fn(state, inputs, r):
        ks = jnp.arange(6, dtype=jnp.int32)
        keys = jnp.where(r == 5, jnp.zeros_like(ks), jnp.where(ks < 2, 0, -1))
        return keys, {"v": jnp.ones((6,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        return state, {"r": r}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=2, n_rounds=1)
    with pytest.warns(RuntimeWarning) as recs:
        run_until(spec, {"x": jnp.zeros((6,), jnp.float32)}, jnp.float32(0.0),
                  _mesh1(), max_rounds=6, min_chunk=2, growth=2)
    msgs = [str(w.message) for w in recs
            if "shuffle overflow" in str(w.message)]
    assert len(msgs) == 1, msgs
    assert "round 5: n_dropped=4" in msgs[0]
    assert "round 3" not in msgs[0]


def test_overflow_one_summary_across_multiple_overflowing_chunks():
    """A job that overflows in TWO different chunks still emits exactly ONE
    summary warning, naming both global round indices — the per-job dedupe
    of `run_until_chunks` (a queued serving job must not flood the log with
    one warning per dispatched chunk). min_chunk=2 + growth=1 splits 4
    rounds into chunks [0,1] and [2,3]; rounds 1 and 3 each overflow."""

    def map_fn(state, inputs, r):
        ks = jnp.arange(6, dtype=jnp.int32)
        overflowing = (r == 1) | (r == 3)
        keys = jnp.where(overflowing, jnp.zeros_like(ks),
                         jnp.where(ks < 2, 0, -1))
        return keys, {"v": jnp.ones((6,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        return state, {"r": r}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=2, n_rounds=1)
    with pytest.warns(RuntimeWarning) as recs:
        run_until(spec, {"x": jnp.zeros((6,), jnp.float32)}, jnp.float32(0.0),
                  _mesh1(), max_rounds=4, min_chunk=2, growth=1)
    msgs = [str(w.message) for w in recs
            if "shuffle overflow" in str(w.message)]
    assert len(msgs) == 1, msgs
    assert "round 1: n_dropped=4" in msgs[0]
    assert "round 3: n_dropped=4" in msgs[0]


# --- workloads through run_until ---------------------------------------------


def test_grep_max_matches_stops_stream_early():
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 8, 512).astype(np.int32)  # dense hits
    pats = np.array([1, 3], np.int32)
    full, per_round_full, _ = grep_count(toks, pats, _mesh1(), n_rounds=8)
    limited, per_round, dropped = grep_count(toks, pats, _mesh1(), n_rounds=8,
                                             max_matches=20)
    assert per_round.shape[0] < 8  # stream stopped early
    assert float(np.sum(np.asarray(limited))) >= 20
    assert float(np.sum(np.asarray(limited))) <= float(np.sum(np.asarray(full)))
    # executed prefix identical to the unlimited stream's rounds
    np.testing.assert_array_equal(np.asarray(per_round),
                                  np.asarray(per_round_full)[: per_round.shape[0]])


def test_sample_sort_halts_when_balanced_and_lossless():
    """A well-conditioned (uniform) input needs no refinement: the halt
    fires on round 0 and the budget is untouched."""
    rng = np.random.default_rng(1)
    v = rng.uniform(0.0, 1.0, 256).astype(np.float32)
    out, counts, dropped = sample_sort(v, _mesh1(), n_rounds=4, lo=0.0, hi=1.0)
    np.testing.assert_array_equal(out, np.sort(v))
    assert counts.sum() == 256
    assert len(dropped) == 1  # halted after the first (already-balanced) round


def test_run_mapreduce_until_engine_entry():
    """engine-level entry: iterate a one-round MapReduce job, folding reduce
    outputs into carried state, until the accumulated total crosses a bound."""
    n = 16

    def map_fn(keys, values):
        return keys % 4, jnp.ones((n,), jnp.float32)

    def reduce_fn(rk, rv, valid):
        got = jnp.sum(jnp.where(valid, rv, 0.0))
        return jax.lax.psum(got, "data")

    spec = MapReduceSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=n)
    res = run_mapreduce_until(
        spec, jnp.arange(n, dtype=jnp.int32), jnp.zeros((n,), jnp.float32),
        jnp.float32(0.0), _mesh1(),
        halt_fn=lambda state, aux, r: state >= 40.0,
        fold_fn=lambda state, out: state + out,
        max_rounds=10,
    )
    # each round contributes 16; 3 rounds reach 48 >= 40
    assert res.rounds_executed == 3 and res.halted
    assert float(res.state) == 48.0
    np.testing.assert_array_equal(res.aux, np.full((3,), 16.0, np.float32))


# --- secure mode --------------------------------------------------------------


@pytest.mark.slow
def test_secure_run_until_bitexact_vs_secure_loop():
    """Secure fused early exit == secure per-round loop stopped at the same
    round, bit-for-bit — and the resumed chunk continues the keystream."""
    mesh = _mesh1()
    cfg = _secure_cfg()
    pts, _ = generate_points(256, 4, seed=5, spread=0.04)
    pts = jnp.asarray(pts)
    lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)
    threshold = float(jnp.linalg.norm(hi - lo)) / 1000.0

    step = make_kmeans_step(mesh, secure=cfg)
    w = jnp.ones((pts.shape[0],), jnp.float32)
    c_loop = pts[:4]
    for it in range(1, 33):
        c_loop, s = step(pts, w, c_loop)
        if np.float32(s) < np.float32(threshold):
            break

    res = kmeans_fit(pts, 4, mesh, secure=cfg, threshold=threshold, max_iter=32,
                     rounds_per_dispatch=4)
    assert res.n_iter == it
    np.testing.assert_array_equal(np.asarray(res.centers), np.asarray(c_loop))
    assert res.n_dispatches < res.n_iter or res.n_iter <= 2


@pytest.mark.slow
def test_secure_halt_round0_single_round_shuffle():
    """halt on round 0 in secure mode == exactly one secure round's output."""
    mesh = _mesh1()
    cfg = _secure_cfg()
    spec1 = make_kmeans_iterative_spec(4, 1, n_rounds=1)
    pts, _ = generate_points(128, 4, seed=8)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((128,), jnp.float32)}
    c0 = jnp.asarray(pts[:4])
    ref, _, _ = run_iterative_mapreduce(spec1, inputs, c0, mesh, secure=cfg)

    halt_spec = make_kmeans_iterative_spec(4, 1, threshold=float("inf"))
    res = run_until(halt_spec, inputs, c0, mesh, secure=cfg,
                    max_rounds=6, min_chunk=3)
    assert res.rounds_executed == 1 and res.halted and res.n_dispatches == 1
    np.testing.assert_array_equal(np.asarray(res.state), np.asarray(ref))

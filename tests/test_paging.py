"""SecurePager: LRU budget semantics, integrity, freshness, stats."""

import numpy as np
import pytest

from repro.core.paging import FreshnessError, IntegrityError, SecurePager

KEY = b"\x11" * 32


def test_under_budget_no_paging():
    p = SecurePager(budget_bytes=1 << 20, key=KEY)
    for i in range(10):
        p.store(f"p{i}", bytes(1000))
    for i in range(10):
        p.load(f"p{i}")
    assert p.stats.evictions == 0 and p.stats.fetches == 0 and p.stats.hits == 10


def test_eviction_and_fetch_roundtrip():
    p = SecurePager(budget_bytes=4096, key=KEY)
    data = {f"p{i}": bytes([i]) * 2048 for i in range(4)}
    for k, v in data.items():
        p.store(k, v)
    assert p.stats.evictions >= 2
    for k, v in data.items():
        assert p.load(k) == v
    assert p.stats.fetches >= 2
    assert p.stats.bytes_encrypted > 0 and p.stats.modeled_seconds > 0


def test_tamper_detected():
    p = SecurePager(budget_bytes=2048, key=KEY)
    p.store("a", b"x" * 2048)
    p.store("b", b"y" * 2048)  # evicts a
    p.tamper("a", 10)
    with pytest.raises(IntegrityError):
        p.load("a")


def test_replay_detected():
    p = SecurePager(budget_bytes=2048, key=KEY)
    p.store("a", b"1" * 2048)
    p.store("b", b"2" * 2048)  # evicts a
    stale = p.capture("a")
    p.load("a")  # fetch a back (evicts b), trusted again
    p.store("c", b"3" * 2048)  # evict a again with a NEW counter
    p.replay("a", stale)
    with pytest.raises(FreshnessError):
        p.load("a")


def test_working_set_cliff_shape():
    """Paging volume explodes once the working set exceeds the budget —
    the mechanism behind the paper's 30% -> >200% overhead cliff."""
    budget = 64 * 1024
    page = 4096

    def paged_bytes(working_set_pages):
        p = SecurePager(budget_bytes=budget, key=KEY)
        ids = [f"p{i}" for i in range(working_set_pages)]
        for i in ids:
            p.store(i, bytes(page))
        for _ in range(3):  # three sequential sweeps (k-means iterations)
            for i in ids:
                p.load(i)
        return p.stats.bytes_encrypted + p.stats.bytes_decrypted

    fits = paged_bytes(8)  # 32 KB working set < 64 KB budget
    over = paged_bytes(64)  # 256 KB working set > 64 KB budget
    assert fits == 0
    assert over > 100 * max(fits, 1)

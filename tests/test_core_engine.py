"""Secure MapReduce engine: bucketing invariants, wordcount, k-means."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh
from repro.core.engine import MapReduceSpec, default_hash, identity_hash, run_mapreduce
from repro.core.kmeans import generate_points, kmeans_fit, kmeans_step_ref, make_kmeans_step
from repro.core.shuffle import SecureShuffleConfig, bucket_pack
from repro.core.wordcount import wordcount
from repro.crypto import chacha


def _mesh1():
    return make_mesh((1,), ("data",))


def _secure_cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x07" * 12),
        counter0=100,
    )


# --- bucket_pack properties ---------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(0, 31), min_size=1, max_size=64),
    st.integers(2, 8),
)
def test_bucket_pack_preserves_multiset(keys, r):
    keys = np.array(keys, np.int32)
    n = len(keys)
    vals = np.arange(n, dtype=np.float32)
    cap = n  # ample capacity
    bk, bv, dropped = bucket_pack(
        jnp.asarray(keys), jnp.asarray(keys) % r, jnp.asarray(vals), r, cap
    )
    assert int(dropped) == 0
    got_k = np.asarray(bk).reshape(-1)
    got_v = np.asarray(bv).reshape(-1)
    mask = got_k >= 0
    # multiset of (key, value) pairs preserved
    got = sorted(zip(got_k[mask].tolist(), got_v[mask].tolist()))
    want = sorted(zip(keys.tolist(), vals.tolist()))
    assert got == want
    # routing correct: row r contains only keys with bucket r
    for row in range(r):
        rk = np.asarray(bk)[row]
        assert np.all((rk < 0) | (rk % r == row))


def test_bucket_pack_overflow_counted():
    keys = jnp.zeros((10,), jnp.int32)  # all to bucket 0
    bk, _, dropped = bucket_pack(keys, keys, jnp.ones((10,)), 2, 4)
    assert int(dropped) == 6
    assert int((np.asarray(bk)[0] >= 0).sum()) == 4


def test_bucket_pack_all_invalid():
    """Every key negative (padding): empty buffer, nothing dropped, and all
    positions map to the R*C drop sentinel."""
    keys = jnp.full((6,), -1, jnp.int32)
    bk, bv, dropped, pos = bucket_pack(
        keys, jnp.zeros((6,), jnp.int32), jnp.arange(6, dtype=jnp.float32), 3, 2,
        return_positions=True,
    )
    assert int(dropped) == 0
    np.testing.assert_array_equal(np.asarray(bk), np.full((3, 2), -1, np.int32))
    np.testing.assert_array_equal(np.asarray(bv), np.zeros((3, 2), np.float32))
    np.testing.assert_array_equal(np.asarray(pos), np.full((6,), 3 * 2, np.int32))


def test_bucket_pack_exact_capacity_fill():
    """Each bucket receives exactly `capacity` items: every slot filled,
    zero drops — the boundary between lossless and overflow."""
    r, cap = 3, 4
    keys = jnp.arange(r * cap, dtype=jnp.int32)
    bucket = keys % r
    bk, bv, dropped = bucket_pack(keys, bucket, keys.astype(jnp.float32), r, cap)
    assert int(dropped) == 0
    bk = np.asarray(bk)
    assert (bk >= 0).all()  # no empty slot anywhere
    for row in range(r):
        np.testing.assert_array_equal(np.sort(bk[row]) % r, np.full(cap, row))


def test_bucket_pack_return_positions_under_overflow():
    """positions is the exact inverse map for surviving items; dropped and
    invalid items both map to the R*C sentinel."""
    r, cap = 2, 3
    #            kept x3 (bucket 0)   dropped   invalid   kept (bucket 1)
    keys = jnp.asarray([10, 11, 12, 13, 14, -1, 20], jnp.int32)
    bucket = jnp.asarray([0, 0, 0, 0, 0, 0, 1], jnp.int32)
    vals = jnp.arange(7, dtype=jnp.float32)
    bk, bv, dropped, pos = bucket_pack(keys, bucket, vals, r, cap,
                                       return_positions=True)
    assert int(dropped) == 2  # items 13, 14 overflow bucket 0
    pos = np.asarray(pos)
    sentinel = r * cap
    np.testing.assert_array_equal(pos, np.array([0, 1, 2, sentinel, sentinel,
                                                 sentinel, cap], np.int32))
    flat_k = np.asarray(bk).reshape(-1)
    flat_v = np.asarray(bv).reshape(-1)
    for i in range(7):
        if pos[i] < sentinel:  # inverse property: slot holds exactly this item
            assert flat_k[pos[i]] == int(keys[i])
            assert flat_v[pos[i]] == float(vals[i])


def test_bucket_pack_empty_trailing_dims():
    """A (n, 0)-shaped value leaf (scalar-per-item pytree leaf with an empty
    trailing dim) must not reach the n_buckets*capacity+1 overflow-slot
    scatter — the guard returns the empty fixed-shape buffer directly, with
    shapes/dtypes consistent with the keyed leaves and overflow still
    counted from the keys."""
    r, cap = 2, 3
    keys = jnp.asarray([10, 11, 12, 13, 14, -1, 20], jnp.int32)
    bucket = jnp.asarray([0, 0, 0, 0, 0, 0, 1], jnp.int32)
    vals = {
        "empty": jnp.zeros((7, 0), jnp.float32),
        "also_empty": jnp.zeros((7, 2, 0), jnp.int32),
        "full": jnp.arange(7, dtype=jnp.float32),
    }
    bk, bv, dropped = bucket_pack(keys, bucket, vals, r, cap)
    assert int(dropped) == 2  # overflow accounting unaffected by empty leaves
    assert bv["empty"].shape == (r, cap, 0)
    assert bv["empty"].dtype == jnp.float32
    assert bv["also_empty"].shape == (r, cap, 2, 0)
    assert bv["also_empty"].dtype == jnp.int32
    # the non-empty leaf routes exactly as it would without the empty ones
    _, bv_ref, _ = bucket_pack(keys, bucket, vals["full"], r, cap)
    np.testing.assert_array_equal(np.asarray(bv["full"]), np.asarray(bv_ref))
    # and the degenerate shape survives a jit boundary
    jitted = jax.jit(lambda k, b, v: bucket_pack(k, b, v, r, cap))
    _, bv2, d2 = jitted(keys, bucket, vals)
    assert int(d2) == 2 and bv2["empty"].shape == (r, cap, 0)


def test_bucket_pack_intra_bucket_order_stable():
    """Items of one bucket keep their input order in the packed row (the
    stable-argsort contract combiners and MoE-style positions rely on)."""
    keys = jnp.asarray([5, 3, 8, 6, 4, 7], jnp.int32)
    bucket = jnp.asarray([1, 0, 1, 0, 1, 0], jnp.int32)
    bk, _, dropped = bucket_pack(keys, bucket, jnp.zeros((6,)), 2, 4)
    assert int(dropped) == 0
    bk = np.asarray(bk)
    np.testing.assert_array_equal(bk[0], np.array([3, 6, 7, -1], np.int32))
    np.testing.assert_array_equal(bk[1], np.array([5, 8, 4, -1], np.int32))


# --- wordcount ---------------------------------------------------------------


@pytest.mark.parametrize("secure", [False, True])
def test_wordcount(secure):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 50, 2000, dtype=np.int32)
    counts, dropped = wordcount(
        toks, 50, _mesh1(), secure=_secure_cfg() if secure else None
    )
    assert int(dropped) == 0
    np.testing.assert_array_equal(np.asarray(counts), np.bincount(toks, minlength=50))


# --- k-means -----------------------------------------------------------------


@pytest.mark.parametrize("secure", [False, True])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_kmeans_step_matches_ref(secure, impl):
    pts, _ = generate_points(512, 8, seed=1)
    centers0 = jnp.asarray(pts[:8])
    step = make_kmeans_step(_mesh1(), secure=_secure_cfg() if secure else None, impl=impl)
    new, shift = step(jnp.asarray(pts), jnp.ones((512,), jnp.float32), centers0)
    ref, shift_ref = kmeans_step_ref(jnp.asarray(pts), centers0)
    np.testing.assert_allclose(np.asarray(new), np.asarray(ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(shift), float(shift_ref), rtol=1e-4)


def test_kmeans_converges_and_recovers_centers():
    pts, true_centers = generate_points(4000, 5, seed=3, spread=0.02)
    res = kmeans_fit(pts, 5, _mesh1(), max_iter=100, init="farthest")
    assert res.n_iter < 100
    # every true center has a recovered center nearby
    d = np.linalg.norm(res.centers[:, None, :] - true_centers[None], axis=-1)
    assert float(d.min(axis=0).max()) < 0.05
    # paper's termination: shift decreases below diag/1000
    assert res.center_shift[-1] < res.center_shift[0]


@pytest.mark.slow
def test_kmeans_secure_equals_plain():
    pts, _ = generate_points(1024, 6, seed=5)
    r_plain = kmeans_fit(pts, 6, _mesh1(), max_iter=20)
    r_sec = kmeans_fit(pts, 6, _mesh1(), secure=_secure_cfg(), max_iter=20)
    assert r_plain.n_iter == r_sec.n_iter
    np.testing.assert_allclose(
        np.asarray(r_plain.centers), np.asarray(r_sec.centers), rtol=1e-4, atol=1e-5
    )


# --- generic engine: mean-by-key with combiner --------------------------------


def test_engine_mean_by_key():
    rng = np.random.default_rng(7)
    n, nk = 512, 16
    keys = jnp.asarray(rng.integers(0, nk, n, dtype=np.int32))
    vals = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))

    def reduce_fn(k, v, valid):
        seg = jnp.where(valid, k, 0)
        s = jax.ops.segment_sum(jnp.where(valid, v["s"], 0.0), seg, num_segments=nk)
        c = jax.ops.segment_sum(jnp.where(valid, v["c"], 0.0), seg, num_segments=nk)
        s = jax.lax.psum(s, "data")
        c = jax.lax.psum(c, "data")
        return s / jnp.maximum(c, 1.0)

    spec = MapReduceSpec(
        map_fn=lambda k, v: (k, {"s": v, "c": jnp.ones_like(v)}),
        reduce_fn=reduce_fn,
        hash_fn=default_hash,
        capacity=n,
    )
    out, dropped = run_mapreduce(spec, keys, vals, _mesh1(), secure=_secure_cfg())
    assert int(dropped) == 0
    want = np.zeros(nk)
    cnt = np.zeros(nk)
    np.add.at(want, np.asarray(keys), np.asarray(vals))
    np.add.at(cnt, np.asarray(keys), 1)
    np.testing.assert_allclose(np.asarray(out), want / np.maximum(cnt, 1), rtol=1e-5)

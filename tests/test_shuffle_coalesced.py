"""Layout-equivalence tests: coalesced single-wire vs per-leaf secure shuffle.

The coalesced wire concatenates every leaf's word rows PACKED into ONE
(R, payload_words) buffer — zero pad bytes travel — encrypts it with one
keystream launch whose per-block counter bases reproduce the per-leaf
counter assignment (keystream is derived block-aligned and sliced to the
packed payload), and moves it with exactly one `lax.all_to_all` per round;
plaintext mode shares the same packed wire topology minus the crypt. These
tests prove the layouts are interchangeable at the BIT level — identical
ciphertext per leaf region, identical decrypted trees, identical
multi-round k-means — across leaf dtypes (u32/i32/f32/bf16), odd word
counts, round ids, and both keystream impls; and they prove the structural
claims (one collective per round, secure AND plaintext; two launches per
secure round) by jaxpr inspection, not accounting.

Property tests use hypothesis when installed and the seeded deterministic
fallback from tests/conftest.py otherwise (same pattern as
tests/test_shuffle_impls.py).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import make_mesh
from repro.core import shuffle
from repro.core.shuffle import (
    COALESCE_ENV,
    SecureShuffleConfig,
    keyed_all_to_all,
    record_wire_bytes,
    resolve_coalesce,
)
from repro.crypto import chacha
from repro.tools.jaxprs import count_primitives

try:
    from repro.kernels.chacha20 import ops  # noqa: F401
except ImportError as e:  # e.g. no Pallas frontend for this platform
    pytest.skip(f"Pallas chacha20 kernel unavailable: {e}", allow_module_level=True)

KW = chacha.key_to_words(bytes(range(32)))
NW = chacha.nonce_to_words(b"\x07" * 12)


def _cfg(impl: str, coalesce="auto", counter0: int = 100) -> SecureShuffleConfig:
    return SecureShuffleConfig(key_words=KW, nonce_words=NW, counter0=counter0,
                               impl=impl, coalesce=coalesce)


def _random_tree(rng, r: int, c: int):
    """A 4-leaf tree covering u32/i32/f32/bf16 wire forms; odd `c` exercises
    odd word counts (bf16 packs to a half-word tail) and sub-block rows."""
    return {
        "f": jnp.asarray(rng.normal(size=(r, c, 3)).astype(np.float32)),
        "h": jnp.asarray(rng.normal(size=(r, c)).astype(np.float32)).astype(jnp.bfloat16),
        "k": jnp.asarray(rng.integers(-5, 100, (r, c)), jnp.int32),
        "u": jnp.asarray(rng.integers(0, 2**32, (r, c), dtype=np.uint32)),
    }


# --- ciphertext-level equivalence ---------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**32 - 1))
def test_coalesced_ciphertext_matches_per_leaf_segments(seed, round_id):
    """Every leaf's region of the coalesced ciphertext is BIT-identical to
    that leaf's per-leaf-path ciphertext, under both impls, for arbitrary
    round ids — the counter-space contract holds across the re-layout."""
    rng = np.random.default_rng(seed)
    r, c = 3, 5
    tree = _random_tree(rng, r, c)
    nonce_ids = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
    ctr_rows = jnp.asarray(rng.integers(0, 2**16, (r,), dtype=np.uint32))
    rid = jnp.uint32(round_id)

    wires, meta, _ = shuffle._pack_wire(tree)
    wire, layout, _ = shuffle._pack_wire_coalesced(tree)
    out = {}
    for impl in ("pallas-interpret", "jnp"):
        enc_leaf = shuffle._crypt_wires(wires, meta, _cfg(impl), nonce_ids,
                                        ctr_rows, rid)
        enc_co = np.asarray(shuffle._crypt_wire_coalesced(
            wire, layout, _cfg(impl), nonce_ids, ctr_rows, rid))
        for leaf_ct, m in zip(enc_leaf, layout.leaves):
            _shape, _dtype, _pad, word_start, n_words, _blocks, _ks = m
            np.testing.assert_array_equal(
                np.asarray(leaf_ct), enc_co[:, word_start:word_start + n_words])
        out[impl] = enc_co
    np.testing.assert_array_equal(out["pallas-interpret"], out["jnp"])


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_coalesced_cross_impl_roundtrip(seed):
    """The jnp oracle decrypts what the Pallas lane kernel encrypted on the
    coalesced wire, back to the exact input bits (incl. bf16 NaN-safety:
    the wire is opaque u32 end to end)."""
    rng = np.random.default_rng(seed)
    r, c = 4, 7
    tree = _random_tree(rng, r, c)
    nonce_ids = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
    ctr_rows = jnp.asarray(rng.integers(0, 2**16, (r,), dtype=np.uint32))
    rid = jnp.uint32(rng.integers(0, 2**32))

    wire, layout, treedef = shuffle._pack_wire_coalesced(tree)
    enc = shuffle._crypt_wire_coalesced(wire, layout, _cfg("pallas-interpret"),
                                        nonce_ids, ctr_rows, rid)
    dec = shuffle._crypt_wire_coalesced(enc, layout, _cfg("jnp"),
                                        nonce_ids, ctr_rows, rid)
    back = shuffle._unpack_wire_coalesced(dec, layout, treedef)
    for leaf, orig in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(
            np.asarray(leaf).view(np.uint8), np.asarray(orig).view(np.uint8))


def test_coalesced_layout_block_alignment():
    """Static layout facts: wire segments are PACKED (zero alignment pad on
    the wire), keystream segments start at block boundaries, counter bases
    reproduce the per-leaf offsets (Σ preceding blocks·R), rowmuls carry
    each leaf's blocks-per-row, zero-size leaves contribute nothing."""
    r, c = 3, 5
    tree = {
        "a": jnp.zeros((r, c), jnp.int32),        # 5 words  -> 1 block
        "b": jnp.zeros((r, c, 7), jnp.float32),   # 35 words -> 3 blocks
        "e": jnp.zeros((r, c, 0), jnp.float32),   # 0 words  -> 0 blocks
    }
    wire, layout, _ = shuffle._pack_wire_coalesced(tree)
    # the wire carries exactly the payload words, back-to-back
    assert wire.shape == (r, layout.payload_words)
    assert layout.payload_words == 5 + 35 + 0
    # the keystream layout stays block-aligned: 4 blocks = 64 words
    assert layout.total_blocks == 4 and layout.total_words == 64
    by_start = sorted(layout.leaves, key=lambda m: m[3])
    assert [m[3] for m in by_start] == [0, 5, 40]   # packed wire offsets
    assert [m[6] for m in by_start] == [0, 16, 64]  # aligned keystream offsets
    assert all(m[6] % 16 == 0 for m in layout.leaves)
    np.testing.assert_array_equal(
        layout.ctr_base, np.array([0, 1 * r + 0, 1 * r + 1, 1 * r + 2], np.uint32))
    np.testing.assert_array_equal(
        layout.ctr_rowmul, np.array([1, 3, 3, 3], np.uint32))


# --- end-to-end through the mesh ----------------------------------------------


def test_keyed_all_to_all_layouts_agree_end_to_end():
    """Plain (coalesced default AND per-leaf), coalesced-secure, and
    per-leaf-secure exchanges return the same bits, and the wire records
    carry the structural counts (1 vs n_leaves collectives, 2 vs 2·n_leaves
    launches, zero pad bytes on the packed wire) plus the per-leaf payload
    breakdown."""
    mesh = make_mesh((1,), ("data",))
    rng = np.random.default_rng(11)
    tree = _random_tree(rng, 1, 5)
    specs = compat.tree_map(lambda _: P("data"), tree)

    def run(sec, coalesce=None):
        body = lambda t: keyed_all_to_all(t, "data", sec,
                                          round_index=jnp.uint32(7),
                                          coalesce=coalesce)
        fn = compat.shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs,
                              check_vma=False)
        return jax.jit(fn)(tree)

    with record_wire_bytes() as recs:
        out_plain = run(None)                 # plaintext, coalesced default
        out_plain_pl = run(None, coalesce=False)
        out_co = run(_cfg("pallas-interpret", True))
        out_pl = run(_cfg("pallas-interpret", False))
    ref = [np.asarray(l).view(np.uint8) for l in jax.tree.leaves(out_plain)]
    for other in (out_plain_pl, out_co, out_pl):
        for a, b in zip(ref, jax.tree.leaves(other)):
            np.testing.assert_array_equal(a, np.asarray(b).view(np.uint8))

    plain_co, plain_pl, co, pl = recs
    n_leaves = len(jax.tree.leaves(tree))
    assert plain_co["coalesced"] and co["coalesced"]
    assert not plain_pl["coalesced"] and not pl["coalesced"]
    # plaintext coalesced: same single-wire topology, no keystream
    assert plain_co["collectives"] == 1 and plain_co["keystream_launches"] == 0
    assert plain_pl["collectives"] == n_leaves
    assert plain_pl["keystream_launches"] == 0
    assert co["collectives"] == 1 and co["keystream_launches"] == 2
    assert pl["collectives"] == n_leaves
    assert pl["keystream_launches"] == 2 * n_leaves
    # zero CTR expansion, leaf by leaf, on both secure layouts
    assert co["per_leaf"] == pl["per_leaf"]
    assert co["bytes"] == pl["bytes"] == sum(co["per_leaf"])
    # the packed wire carries ZERO pad bytes — secure and plaintext alike
    for rec in (plain_co, plain_pl, co, pl):
        assert rec["pad_bytes"] == 0 and rec["wire_bytes"] == rec["bytes"]


# --- structural proof: one all_to_all per secure round ------------------------


@pytest.mark.parametrize("coalesce,want_a2a,want_launches",
                         [(True, 1, 2), (False, 3, 6)])
def test_jaxpr_collectives_per_secure_round(coalesce, want_a2a, want_launches):
    """Jaxpr inspection of the fused driver round: the ≥3-leaf k-means tree
    ({k} + {s, c}) traces exactly ONE all_to_all and TWO pallas_call
    keystream launches per secure round when coalesce=True — and the
    per-leaf oracle traces one collective and two launches PER LEAF."""
    from repro.core.driver import make_iterative_runner
    from repro.core.kmeans import generate_points, make_kmeans_iterative_spec

    mesh = make_mesh((1,), ("data",))
    pts, _ = generate_points(64, 4, seed=5)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((64,), jnp.float32)}
    spec = make_kmeans_iterative_spec(4, 1, n_rounds=2)
    c0 = jnp.asarray(pts[:4])
    runner = make_iterative_runner(
        spec, mesh, secure=_cfg("pallas-interpret", coalesce))
    jaxpr = jax.make_jaxpr(runner.abstract_fn)(inputs, c0, jnp.uint32(0))
    # the scan body traces once, so whole-program counts ARE per-round counts
    assert count_primitives(jaxpr, "all_to_all") == want_a2a
    assert count_primitives(jaxpr, "pallas_call") == want_launches


@pytest.mark.parametrize("coalesce,want_a2a", [(True, 1), (False, 3)])
def test_jaxpr_collectives_per_plaintext_round(coalesce, want_a2a):
    """Plaintext (`secure=None`) rounds ride the same packed single-wire
    topology: ONE all_to_all per round by default (per-leaf with
    coalesce=False), and ZERO keystream launches either way — so a
    secure-vs-plain jaxpr diff isolates the crypt, not the wire shape."""
    from repro.core.driver import make_iterative_runner
    from repro.core.kmeans import generate_points, make_kmeans_iterative_spec

    mesh = make_mesh((1,), ("data",))
    pts, _ = generate_points(64, 4, seed=5)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((64,), jnp.float32)}
    spec = make_kmeans_iterative_spec(4, 1, n_rounds=2)
    c0 = jnp.asarray(pts[:4])
    runner = make_iterative_runner(spec, mesh, secure=None, coalesce=coalesce)
    jaxpr = jax.make_jaxpr(runner.abstract_fn)(inputs, c0, jnp.uint32(0))
    assert count_primitives(jaxpr, "all_to_all") == want_a2a
    assert count_primitives(jaxpr, "pallas_call") == 0


# --- selector resolution ------------------------------------------------------


def test_resolve_coalesce_env_and_explicit(monkeypatch, no_calibration):
    monkeypatch.delenv(COALESCE_ENV, raising=False)
    assert resolve_coalesce("auto") is True
    assert resolve_coalesce(None) is True
    assert resolve_coalesce(True) is True
    assert resolve_coalesce(False) is False

    monkeypatch.setenv(COALESCE_ENV, "0")
    assert resolve_coalesce("auto") is False
    # an explicit bool always wins over the environment
    assert resolve_coalesce(True) is True
    monkeypatch.setenv(COALESCE_ENV, "true")
    assert resolve_coalesce("auto") is True

    monkeypatch.setenv(COALESCE_ENV, "sideways")
    with pytest.raises(ValueError, match=rf"\${COALESCE_ENV}='sideways'"):
        resolve_coalesce("auto")
    monkeypatch.delenv(COALESCE_ENV, raising=False)
    with pytest.raises(ValueError) as ei:
        resolve_coalesce("sideways")
    assert COALESCE_ENV not in str(ei.value)


def test_with_coalesce_override():
    cfg = _cfg("auto")
    assert cfg.with_coalesce(None) is cfg
    assert cfg.with_coalesce("auto") is cfg
    over = cfg.with_coalesce(False)
    assert over.coalesce is False and over.impl == cfg.impl
    assert cfg.coalesce == "auto"  # frozen: original untouched


# --- multi-round driver: fused secure k-means identical across layouts --------


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["pallas-interpret", "jnp"])
def test_secure_kmeans_multiround_bitexact_across_layouts(impl):
    """Acceptance anchor: a fused multi-round secure k-means run produces
    bit-identical centers/shifts whether the wire is coalesced or per-leaf
    (exercises the `coalesce` plumbing through driver entry points), under
    both keystream impls."""
    from repro.core.driver import run_iterative_mapreduce
    from repro.core.kmeans import generate_points, make_kmeans_iterative_spec

    mesh = make_mesh((1,), ("data",))
    pts, _ = generate_points(256, 4, seed=5)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((256,), jnp.float32)}
    spec = make_kmeans_iterative_spec(4, 1, n_rounds=2)
    c0 = jnp.asarray(pts[:4])
    out = {}
    for coalesce in (True, False):
        final, aux, dropped = run_iterative_mapreduce(
            spec, inputs, c0, mesh, secure=_cfg(impl), coalesce=coalesce)
        assert int(np.asarray(dropped).sum()) == 0
        out[coalesce] = (np.asarray(final), np.asarray(aux["shift"]),
                         np.asarray(aux["centers"]))
    for a, b in zip(out[True], out[False]):
        np.testing.assert_array_equal(a, b)

"""Sharded carried state: the driver's two-tier (replicated | sharded) contract.

`IterativeSpec.state_specs` lets any carried-state leaf stay `P(axis)`-sharded
across rounds instead of being re-replicated by an all_gather every round.
These tests pin the contract from every side:

  * STRUCTURAL PROOF (jaxpr, not accounting): a sharded sort round traces
    exactly ONE all_to_all — secure AND plaintext — and exactly one fewer
    all_gather than the replicated layout, with ZERO other collectives of
    any kind added or removed (`repro.tools.jaxprs.collective_counts`).
  * BIT-IDENTITY: sharded and replicated layouts produce identical final
    state after the final host gather — swept over mixed `P()`/`P(axis)`
    trees, u32/f32/bf16 resident leaves, and halt-early vs full-budget
    chunked runs (multi-device subprocess, like tests/test_driver.py).
  * HALT GUARD: `halt_fn` touching a sharded leaf raises a trace-time
    ValueError naming the leaf (a shard-varying predicate would deadlock
    the mesh), while replicated leaves and aux stay usable.
  * SPEC RESOLUTION: None defaults to all-`P()`, a bare PartitionSpec
    broadcasts, structure mismatches and non-PartitionSpec leaves raise at
    build time; `resolve_state_mode` honors $REPRO_STATE_SPECS the same way
    the chacha/coalesce selectors honor theirs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from conftest import run_in_subprocess as _run
from repro.compat import make_mesh
from repro.core.driver import (
    STATE_SPECS_ENV,
    IterativeSpec,
    _resolve_state_specs,
    make_iterative_runner,
    resolve_state_mode,
    run_until,
)
from repro.core.engine import identity_hash
from repro.core.shuffle import SecureShuffleConfig
from repro.core.sort import make_sample_sort_spec
from repro.crypto import chacha
from repro.tools.jaxprs import collective_counts


def _mesh1():
    return make_mesh((1,), ("data",))


def _secure_cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x13" * 12),
        counter0=9,
        impl="pallas-interpret",
    )


def _dummy_spec(**kw) -> IterativeSpec:
    """Spec shell for resolution tests (fns never called)."""
    return IterativeSpec(map_fn=lambda *a: None, reduce_fn=lambda *a: None, **kw)


# --- selector / spec resolution -----------------------------------------------


def test_resolve_state_mode_env_and_explicit(monkeypatch):
    monkeypatch.delenv(STATE_SPECS_ENV, raising=False)
    assert resolve_state_mode("auto") == "sharded"
    assert resolve_state_mode(None) == "sharded"
    assert resolve_state_mode("replicated") == "replicated"

    monkeypatch.setenv(STATE_SPECS_ENV, "replicated")
    assert resolve_state_mode("auto") == "replicated"
    # an explicit mode always wins over the environment
    assert resolve_state_mode("sharded") == "sharded"

    monkeypatch.setenv(STATE_SPECS_ENV, "sideways")
    with pytest.raises(ValueError, match=rf"\${STATE_SPECS_ENV}='sideways'"):
        resolve_state_mode("auto")
    monkeypatch.delenv(STATE_SPECS_ENV, raising=False)
    with pytest.raises(ValueError) as ei:
        resolve_state_mode("sideways")
    assert STATE_SPECS_ENV not in str(ei.value)


def test_state_specs_none_and_bare_spec_broadcast():
    state = {"a": jnp.zeros((2,)), "b": {"c": jnp.zeros((3,))}}
    tree, sharded = _resolve_state_specs(_dummy_spec(), state)
    assert jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)) == [P(), P()]
    assert sharded == [False, False]
    # a single bare PartitionSpec broadcasts to every leaf
    tree, sharded = _resolve_state_specs(_dummy_spec(state_specs=P("data")), state)
    assert sharded == [True, True]
    tree, sharded = _resolve_state_specs(_dummy_spec(state_specs=P()), state)
    assert sharded == [False, False]
    # per-leaf trees may mix tiers, and a None leaf means replicated
    tree, sharded = _resolve_state_specs(
        _dummy_spec(state_specs={"a": P("data"), "b": {"c": None}}), state)
    assert sharded == [True, False]


def test_state_specs_structure_mismatch_and_bad_leaf_raise():
    state = {"a": jnp.zeros((2,)), "b": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="state_specs"):
        _resolve_state_specs(_dummy_spec(state_specs={"a": P()}), state)
    with pytest.raises(ValueError, match="PartitionSpec"):
        _resolve_state_specs(_dummy_spec(state_specs={"a": P(), "b": "data"}), state)


def test_runner_rejects_mismatched_state_specs_at_dispatch():
    spec = _counting_spec(sharded=True)
    spec = IterativeSpec(
        map_fn=spec.map_fn, reduce_fn=spec.reduce_fn, hash_fn=spec.hash_fn,
        capacity=spec.capacity, n_rounds=spec.n_rounds,
        state_specs={"wrong_key": P()})
    runner = make_iterative_runner(spec, _mesh1())
    with pytest.raises(ValueError, match="state_specs"):
        runner(_INPUTS, _counting_state())


# --- structural proof: sharded sort round collectives -------------------------


def _sort_jaxpr_counts(shard_state: bool, secure):
    """Collective counts of one traced sort chunk on a 1-axis mesh."""
    mesh = _mesh1()
    r, n = 1, 32
    spec = make_sample_sort_spec(r, n, halt_total=n, shard_state=shard_state)
    runner = make_iterative_runner(spec, mesh, secure=secure)
    inputs = {"v": jnp.zeros((n,), jnp.float32)}
    state = {
        "edges": jnp.zeros((r + 1,), jnp.float32),
        "sorted": jnp.full((r, r * n), jnp.inf, jnp.float32),
        "counts": jnp.zeros((r,), jnp.float32),
    }
    jaxpr = jax.make_jaxpr(runner.abstract_fn)(inputs, state, jnp.uint32(0))
    return collective_counts(jaxpr)


@pytest.mark.parametrize("secure", [False, True], ids=["plaintext", "secure"])
def test_jaxpr_sharded_sort_round_drops_all_gather_only(secure):
    """The tentpole's acceptance proof: porting the sort table to `P(axis)`
    removes exactly ONE all_gather per round (the table re-replication) and
    changes NOTHING else — still exactly one all_to_all per round, secure
    and plaintext alike, and zero collectives of any other kind appear."""
    cfg = _secure_cfg() if secure else None
    sharded = _sort_jaxpr_counts(True, cfg)
    replicated = _sort_jaxpr_counts(False, cfg)
    # the wire stays a single coalesced all_to_all in both layouts
    assert sharded["all_to_all"] == replicated["all_to_all"] == 1
    # the per-round table all_gather is GONE (counts-gather remains)
    assert replicated["all_gather"] == sharded["all_gather"] + 1
    assert sharded["all_gather"] >= 1
    # ... and nothing else moved: no new collective of any kind
    for name in sharded:
        if name != "all_gather":
            assert sharded[name] == replicated[name], name


# --- halt guard ---------------------------------------------------------------


def _counting_state():
    return {"big": jnp.zeros((1, 4), jnp.float32), "tot": jnp.float32(0.0)}


_INPUTS = {"x": jnp.zeros((4,), jnp.float32)}


def _counting_spec(sharded: bool, halt_fn=None) -> IterativeSpec:
    """1-shard job: 'big' is a resident per-reducer row, 'tot' a replicated
    running total (psum'd). On a 1-device mesh the sharded local shard and
    the replicated value coincide, so the same fns serve both layouts."""

    def map_fn(state, inputs, r):
        return jnp.zeros((4,), jnp.int32), {"v": jnp.ones((4,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        got = lax.psum(jnp.sum(jnp.where(valid, rv["v"], 0.0)), "data")
        return ({"big": state["big"] + got, "tot": state["tot"] + got},
                {"t": got})

    return IterativeSpec(
        map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash, capacity=4,
        n_rounds=2, halt_fn=halt_fn,
        state_specs={"big": P("data") if sharded else P(), "tot": P()})


def test_halt_fn_touching_sharded_leaf_raises_at_trace_time():
    spec = _counting_spec(
        sharded=True, halt_fn=lambda state, aux, r: jnp.sum(state["big"]) > 9.0)
    runner = make_iterative_runner(spec, _mesh1())
    with pytest.raises(ValueError, match=r"SHARDED carried-state leaf "
                                         r"state\['big'\]"):
        runner(_INPUTS, _counting_state())


def test_halt_fn_on_replicated_leaves_still_works_alongside_sharded():
    """Replicated leaves, aux, and the round index stay fully usable in
    halt_fn even when a sibling leaf is sharded-and-guarded."""
    spec = _counting_spec(
        sharded=True,
        halt_fn=lambda state, aux, r: (state["tot"] + aux["t"] * 0 >= 8.0))
    res = run_until(spec, _INPUTS, _counting_state(), _mesh1(), max_rounds=6)
    assert res.halted and res.rounds_executed == 2  # tot: 4.0 then 8.0
    np.testing.assert_array_equal(np.asarray(res.state["big"]),
                                  np.full((1, 4), 8.0, np.float32))


def test_sharded_and_replicated_layouts_bit_identical_1dev():
    """Smoke-level bit-identity (the real multi-device sweep runs below in a
    subprocess): same job, both layouts, identical state and aux."""
    halt = lambda state, aux, r: state["tot"] >= 12.0
    out = {}
    for sharded in (False, True):
        res = run_until(_counting_spec(sharded, halt_fn=halt), _INPUTS,
                        _counting_state(), _mesh1(), max_rounds=8, min_chunk=2)
        out[sharded] = res
    assert out[True].rounds_executed == out[False].rounds_executed == 3
    np.testing.assert_array_equal(np.asarray(out[True].state["big"]),
                                  np.asarray(out[False].state["big"]))
    np.testing.assert_array_equal(np.asarray(out[True].aux["t"]),
                                  np.asarray(out[False].aux["t"]))


# --- sort spec wiring ---------------------------------------------------------


def test_sort_spec_state_specs_follow_shard_state(monkeypatch):
    assert make_sample_sort_spec(2, 4, shard_state=True).state_specs["sorted"] == P("data")
    assert make_sample_sort_spec(2, 4, shard_state=False).state_specs["sorted"] == P()
    monkeypatch.delenv(STATE_SPECS_ENV, raising=False)
    auto = make_sample_sort_spec(2, 4)  # 'auto' → env default 'sharded'
    assert auto.state_specs["sorted"] == P("data")
    monkeypatch.setenv(STATE_SPECS_ENV, "replicated")
    assert make_sample_sort_spec(2, 4).state_specs["sorted"] == P()
    # edges/counts drive refinement + halting: replicated in BOTH layouts
    for spec in (auto, make_sample_sort_spec(2, 4, shard_state=True)):
        assert spec.state_specs["edges"] == P()
        assert spec.state_specs["counts"] == P()


# --- multi-device: bit-identity sweep + sort end-to-end -----------------------


def test_sharded_state_property_sweep_multidev():
    """Mixed P()/P(axis) trees x u32/f32/bf16 resident leaves x halt-early vs
    full-budget chunked runs: sharded and replicated layouts are bit-identical
    after the final gather, on a real 4-way mesh, with run_until's default
    state donation in force."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh
    from repro.core.driver import IterativeSpec, run_until
    from repro.core.engine import identity_hash

    R, C = 4, 8
    mesh = make_mesh((R,), ("data",))
    inputs = {"x": jnp.zeros((R,), jnp.float32)}

    def make_spec(dtype, sharded, halt_at):
        def map_fn(state, inputs, r):
            # every shard sends one unit item to every reducer
            return jnp.arange(R, dtype=jnp.int32), {"v": jnp.ones((R,), jnp.float32)}

        def reduce_fn(state, rk, rv, valid, r):
            got = jnp.sum(jnp.where(valid, rv["v"], 0.0))      # local: R items
            tot = state["tot"] + lax.psum(got, "data")
            inc = got.astype(dtype)
            if sharded:
                big = state["big"] + inc                       # local (1, C) row
            else:
                row = state["big"][lax.axis_index("data")] + inc
                big = lax.all_gather(row, "data")              # re-replicate
            return {"big": big, "tot": tot}, {"tot": tot}

        halt_fn = None
        if halt_at is not None:
            halt_fn = lambda state, aux, r: aux["tot"] >= halt_at
        return IterativeSpec(
            map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
            capacity=R, n_rounds=1, halt_fn=halt_fn,
            state_specs={"big": P("data") if sharded else P(), "tot": P()})

    for dtype in (jnp.uint32, jnp.float32, jnp.bfloat16):
        # halt at 3 executed rounds (tot grows R*R per round) vs full budget
        for halt_at in (3.0 * R * R, None):
            out = {}
            for sharded in (False, True):
                init = {"big": jnp.zeros((R, C), dtype), "tot": jnp.float32(0.0)}
                res = run_until(make_spec(dtype, sharded, halt_at), inputs, init,
                                mesh, max_rounds=5, min_chunk=2)
                out[sharded] = (np.asarray(res.state["big"]),
                                float(res.state["tot"]),
                                res.rounds_executed, res.halted)
            rep, sh = out[False], out[True]
            np.testing.assert_array_equal(rep[0], sh[0])
            assert rep[1:] == sh[1:], (dtype, halt_at, rep, sh)
            want_rounds = 3 if halt_at is not None else 5
            assert sh[2] == want_rounds and sh[3] == (halt_at is not None)
    print("OK")
    """, devices=4)


def test_sample_sort_8dev_bit_identical_sharded_vs_replicated():
    """End-to-end acceptance: the 8-device sampling sort returns identical
    output/counts/drop history with the resident-sharded table and with the
    historical replicated one."""
    _run("""
    import numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core.sort import sample_sort
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(3)
    v = (rng.exponential(scale=0.15, size=512) % 1.0).astype(np.float32)
    out = {}
    for sharded in (False, True):
        out[sharded] = sample_sort(v, mesh, n_rounds=3, capacity=16,
                                   lo=0.0, hi=1.0, shard_state=sharded)
    np.testing.assert_array_equal(out[True][0], out[False][0])
    np.testing.assert_array_equal(out[True][1], out[False][1])
    np.testing.assert_array_equal(np.asarray(out[True][2]),
                                  np.asarray(out[False][2]))
    np.testing.assert_array_equal(out[True][0], np.sort(v))
    print("OK")
    """)

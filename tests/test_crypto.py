"""Crypto substrate tests: RFC 8439 vectors, roundtrips, MAC properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.crypto import chacha, ctr, keys, mac

# --- RFC 8439 test vectors (shared with the kernel suite) --------------------

from rfc_vectors import (  # noqa: E402
    RFC_BLOCK_232,
    RFC_CIPHERTEXT,
    RFC_KEY,
    RFC_NONCE_232,
    RFC_NONCE_242,
    RFC_PLAINTEXT,
)


def test_rfc8439_block_jnp():
    kw = chacha.key_to_words(RFC_KEY)
    nw = chacha.nonce_to_words(RFC_NONCE_232)
    out = np.asarray(chacha.chacha20_block_words(kw, jnp.array([1], jnp.uint32), nw))
    np.testing.assert_array_equal(out[0], RFC_BLOCK_232)


def test_rfc8439_block_numpy():
    kw = chacha.key_to_words(RFC_KEY)
    nw = chacha.nonce_to_words(RFC_NONCE_232)
    out = chacha._chacha20_blocks_np(kw, np.array([1], np.uint32), nw)
    np.testing.assert_array_equal(out[0], RFC_BLOCK_232)


def test_rfc8439_encrypt_bytes():
    ct = chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_242, 1, RFC_PLAINTEXT)
    assert ct == RFC_CIPHERTEXT
    pt = chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_242, 1, ct)
    assert pt == RFC_PLAINTEXT


def test_keystream_words_match_bytes():
    kw = chacha.key_to_words(RFC_KEY)
    nw = chacha.nonce_to_words(RFC_NONCE_242)
    words = np.asarray(chacha.chacha20_keystream_words(kw, nw, 1, 40))
    raw = chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_242, 1, b"\x00" * 160)
    np.testing.assert_array_equal(words, np.frombuffer(raw, "<u4")[:40])


# --- array / pytree CTR ------------------------------------------------------

KW = chacha.key_to_words(RFC_KEY)
NW = chacha.nonce_to_words(RFC_NONCE_242)


@pytest.mark.parametrize(
    "shape,dtype",
    [
        ((7,), jnp.float32),
        ((3, 5), jnp.float32),
        ((4, 4), jnp.bfloat16),
        ((9,), jnp.int32),
        ((2, 3, 5), jnp.uint8),
        ((6,), jnp.int8),
        ((5,), jnp.uint16),
    ],
)
def test_ctr_roundtrip_dtypes(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape).astype(jnp.float32)
    if jnp.issubdtype(dtype, jnp.integer):
        x = (x * 10).astype(dtype)
    else:
        x = x.astype(dtype)
    enc = ctr.encrypt_array(x, KW, NW, 0)
    assert enc.shape == x.shape and enc.dtype == x.dtype
    dec = ctr.decrypt_array(enc, KW, NW, 0)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(x))
    # ciphertext differs from plaintext (keystream nonzero w.h.p.)
    assert not np.array_equal(np.asarray(enc).view(np.uint8), np.asarray(x).view(np.uint8))


def test_ctr_encrypt_matches_bytes_path():
    """In-graph CTR over u32 words == host byte-path encryption."""
    x = jnp.arange(37, dtype=jnp.uint32)
    enc = np.asarray(ctr.encrypt_array(x, KW, NW, 0))
    host = chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_242, 0, np.asarray(x).tobytes())
    np.testing.assert_array_equal(enc, np.frombuffer(host, "<u4"))


def test_ctr_tree_roundtrip_and_disjoint_counters():
    tree = {
        "a": jnp.ones((17,), jnp.float32),
        "b": (jnp.arange(5, dtype=jnp.int32), jnp.full((2, 9), 0.5, jnp.bfloat16)),
    }
    enc, ctr_end = ctr.encrypt_tree(tree, KW, NW, 0)
    assert ctr_end == ctr.tree_counter_blocks(tree)
    dec, _ = ctr.decrypt_tree(enc, KW, NW, 0)
    for l0, l1 in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    # leaves use disjoint counter ranges: identical plaintexts -> different ct
    t2 = {"a": jnp.zeros((16,), jnp.uint32), "b": jnp.zeros((16,), jnp.uint32)}
    e2, _ = ctr.encrypt_tree(t2, KW, NW, 0)
    assert not np.array_equal(np.asarray(e2["a"]), np.asarray(e2["b"]))


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=300), st.integers(0, 2**30))
def test_hypothesis_bytes_roundtrip(data, counter):
    ct = chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_232, counter, data)
    assert len(ct) == len(data)
    assert chacha.chacha20_encrypt_bytes(RFC_KEY, RFC_NONCE_232, counter, ct) == data
    if len(data) >= 8:
        assert ct != data


# --- MAC ---------------------------------------------------------------------


def test_mac_jnp_matches_host():
    rs, ss = mac.mac_keys_from_keystream(KW, NW, 7)
    msg = np.arange(100, dtype=np.uint32) * np.uint32(2654435761)
    t_host = mac.mac_tag_host(msg, rs, ss)
    t_dev = np.asarray(mac.mac_tag_words(jnp.asarray(msg), jnp.asarray(rs), jnp.asarray(ss)))
    np.testing.assert_array_equal(t_host, t_dev)


def test_mac_detects_tamper():
    rs, ss = mac.mac_keys_from_keystream(KW, NW, 3)
    msg = np.arange(64, dtype=np.uint32)
    tag = mac.mac_tag_host(msg, rs, ss)
    bad = msg.copy()
    bad[10] ^= 1
    assert not mac.mac_verify_host(bad, rs, ss, tag)
    assert mac.mac_verify_host(msg, rs, ss, tag)


def test_mac_length_extension_guard():
    rs, ss = mac.mac_keys_from_keystream(KW, NW, 3)
    a = np.zeros(4, np.uint32)
    b = np.zeros(5, np.uint32)
    assert not np.array_equal(mac.mac_tag_host(a, rs, ss), mac.mac_tag_host(b, rs, ss))


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64),
    st.integers(0, 63),
    st.integers(1, 2**31 - 1),
)
def test_hypothesis_mac_tamper(words, pos, delta):
    rs, ss = mac.mac_keys_from_keystream(KW, NW, 11)
    msg = np.array(words, np.uint32)
    tag = mac.mac_tag_host(msg, rs, ss)
    bad = msg.copy()
    i = pos % len(bad)
    bad[i] = np.uint32((int(bad[i]) + delta) % (2**32))
    if np.array_equal(bad % np.uint64(mac.P31), msg % np.uint64(mac.P31)):
        return  # same residues -> same tag by design (31-bit field)
    assert not np.array_equal(mac.mac_tag_host(bad, rs, ss), tag)


def test_mulmod31_exhaustive_random():
    rng = np.random.default_rng(0)
    a = rng.integers(0, mac.P31, size=200, dtype=np.uint32)
    b = rng.integers(0, mac.P31, size=200, dtype=np.uint32)
    got = np.asarray(mac._mulmod31(jnp.asarray(a), jnp.asarray(b)))
    want = ((a.astype(np.uint64) * b.astype(np.uint64)) % np.uint64(mac.P31)).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


# --- keys / attestation -------------------------------------------------------


def test_key_hierarchy_and_attestation():
    kh = keys.KeyHierarchy(master=b"\x42" * 32)
    m = kh.attestation.enroll(b"worker-code-v1")
    sk = kh.release_keys(m)
    assert sk.data != sk.code and len(sk.data) == 32
    with pytest.raises(PermissionError):
        kh.release_keys(keys.Attestation.measure(b"evil-code"))
    # wrap/unwrap roundtrip
    kek = b"\x99" * 32
    wrapped = kh.wrap_key("data", kek)
    assert wrapped != sk.data
    assert keys.KeyHierarchy.unwrap_key("data", kek, wrapped) == sk.data


def test_derive_key_deterministic_and_distinct():
    m = b"\x01" * 32
    assert keys.derive_key(m, "data") == keys.derive_key(m, "data")
    assert keys.derive_key(m, "data") != keys.derive_key(m, "code")

"""Checkpoint manager: atomicity, integrity, elastic restore, data cursor."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.compat import make_mesh


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": rng.normal(size=(4, 8, 8)).astype(np.float32),
                   "b": rng.normal(size=(4, 8)).astype(np.float32)},
        "embed": rng.normal(size=(32, 8)).astype(np.float32),
        "count": np.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(10, t, extra={"data_cursor": {"ctr": 123}})
    restored, extra = mgr.restore(10, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)
    assert extra["data_cursor"]["ctr"] == 123


def test_tamper_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    path = mgr.save(5, t)
    # flip one byte in a shard file
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    p = os.path.join(path, fn)
    data = bytearray(open(p, "rb").read())
    data[-1] ^= 0xFF
    open(p, "wb").write(bytes(data))
    with pytest.raises(CheckpointError, match="MAC"):
        mgr.restore(5, jax.tree.map(np.zeros_like, t))


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.list_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    bad = dict(t, embed=np.zeros((16, 8), np.float32))
    with pytest.raises(CheckpointError, match="shape"):
        mgr.restore(1, bad)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save under one sharding, restore onto a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh1 = make_mesh((1,), ("data",))
    mgr.save(1, t)
    sh = {"w": NamedSharding(mesh1, P("data", None))}
    restored, _ = mgr.restore(1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]


def test_train_resume_bitexact(tmp_path):
    """Interrupt-and-resume training reproduces the uninterrupted run."""
    from repro.configs import get_config
    from repro.crypto.keys import make_session_keys
    from repro.data.pipeline import SecureShardedSource
    from repro.data.synthetic import synthetic_tokens
    from repro.models.lm import init_params
    from repro.optim.adamw import adamw_init
    from repro.train.step import SecureIngest, make_train_step

    cfg = get_config("rwkv6-1.6b").reduced()
    mesh = make_mesh((1,), ("data",))
    session = make_session_keys(b"\x21" * 32)
    ingest = SecureIngest(key_words=session.words("data"),
                          nonce_words=session.nonce_words("data", 0))
    toks = synthetic_tokens(2000, cfg.vocab_size, seed=1)

    def run(n_steps, resume_from=None):
        src = SecureShardedSource(toks, batch=2, seq=16, session=session, seed=3)
        step_fn, _, _ = make_train_step(cfg, mesh, secure_ingest=ingest, donate=False)
        params = init_params(cfg, jax.random.key(0))
        opt = adamw_init(params)
        start = 0
        if resume_from is not None:
            mgr, at = resume_from
            (params, opt), extra = mgr.restore(at, (params, opt))
            src.restore(extra["data_cursor"])
            start = extra["step"]
        for i in range(start, n_steps):
            batch = src.next_batch()
            params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        return params, metrics

    # uninterrupted 4 steps
    p_full, m_full = run(4)

    # 2 steps -> checkpoint -> resume 2 more
    src = SecureShardedSource(toks, batch=2, seq=16, session=session, seed=3)
    step_fn, _, _ = make_train_step(cfg, mesh, secure_ingest=ingest, donate=False)
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    for i in range(2):
        batch = src.next_batch()
        params, opt, _ = step_fn(params, opt, batch, jnp.int32(i))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, (params, opt), extra={"step": 2, "data_cursor": src.state})
    p_res, m_res = run(4, resume_from=(mgr, 2))

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)

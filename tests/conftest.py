"""Tier-1 test configuration: seeded fallback when `hypothesis` is absent.

The property tests (`test_core_engine`, `test_crypto`, `test_rwkv_wkv`) use
hypothesis when it is installed (see requirements-dev.txt). On machines
without it, this conftest registers a minimal deterministic stand-in under
the same import name BEFORE test modules are collected, so the suite still
collects and the property tests run against a fixed seeded sample of cases
instead of erroring at import time.

The stand-in implements exactly the surface the suite uses:
  * `given(*strategies)` / `settings(max_examples=..., deadline=...)`
  * `strategies.integers / lists / binary`
Draws come from one `numpy` Generator with a fixed seed, so a fallback run
is reproducible — weaker than hypothesis (no shrinking, no example
database), but a real execution of every property rather than a skip.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import types

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


import pytest


@pytest.fixture
def no_calibration(monkeypatch):
    """Pin the `auto` resolvers to their historical defaults.

    The CI tier1-autotune lane runs this suite WITH $REPRO_CALIBRATION set,
    under which `auto` knobs follow the calibrated model instead of the
    hard-coded fallbacks. Tests that assert the fallback values (the
    no-calibration contract) opt into this fixture: it strips the env var
    and forces the active model OFF for the test's duration.
    """
    from repro.perf.model import clear_active_model, set_active_model
    from repro.perf.calibrate import CALIBRATION_ENV

    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    set_active_model(None)  # forced off — wins over any cached env model
    yield
    clear_active_model()


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 420):
    """Run a snippet in a fresh interpreter with N forced host devices.

    Shared by the multi-device suites (test_distributed, test_driver):
    device-count forcing must happen before jax initializes, hence the
    subprocess.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout

try:  # real hypothesis wins whenever it is importable
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as np

    _SEED = 0x5EED
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]

        return _Strategy(draw)

    def _binary(min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return bytes(rng.integers(0, 256, size=size, dtype=np.uint8))

        return _Strategy(draw)

    def _given(*strategies):
        def decorate(fn):
            # no functools.wraps: copying __wrapped__ would make pytest
            # resolve the original argument names as fixtures
            def wrapper():
                n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strategies))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._hypothesis_fallback = True
            return wrapper

        return decorate

    def _settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def decorate(fn):
            # applied above @given: the wrapper reads this attribute off itself
            fn._fallback_max_examples = max_examples
            return fn

        return decorate

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.__doc__ = "Deterministic seeded fallback registered by tests/conftest.py"
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.lists = _lists
    _st.binary = _binary
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

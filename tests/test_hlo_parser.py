"""HLO cost-engine tests: loop-aware flop/collective attribution."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.tools.hlo import parse_hlo_costs, roofline_terms


def test_scan_flops_multiplied_by_trip_count():
    d = 128
    def scanned(x, ws):
        def body(c, w):
            return c @ w, ()
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((8, d, d), jnp.float32),
    ).compile()
    p = parse_hlo_costs(c.as_text())
    assert p["flops"] == pytest.approx(2 * d**3 * 8, rel=0.01)
    assert not p["warnings"]


def test_nested_scan_flops():
    d = 64
    def nested(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, ()
            return jax.lax.scan(inner, c, jnp.arange(3))[0], ()
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(nested).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((5, d, d), jnp.float32),
    ).compile()
    p = parse_hlo_costs(c.as_text())
    assert p["flops"] == pytest.approx(2 * d**3 * 15, rel=0.01)


def test_dot_contraction_dims():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    p = parse_hlo_costs(c.as_text())
    assert p["flops"] == pytest.approx(2 * 32 * 48 * 16, rel=0.01)


def test_roofline_terms_dominance():
    t = roofline_terms({}, {"flops": 197e12, "bytes": 1.0, "link_bytes": 0.0}, 1)
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms({}, {"flops": 1.0, "bytes": 819e9 * 2, "link_bytes": 0.0}, 1)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(2.0)
    t = roofline_terms({}, {"flops": 0.0, "bytes": 0.0, "link_bytes": 50e9 * 3}, 1)
    assert t["dominant"] == "collective" and t["collective_s"] == pytest.approx(3.0)


def test_bytes_exclude_bookkeeping():
    """tuple/get-tuple-element/bitcast contribute zero bytes."""
    d = 256
    c = jax.jit(lambda x: (x, x.T)).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32)
    ).compile()
    p = parse_hlo_costs(c.as_text())
    # only the transpose/copy should count: well under 10x the array size
    assert p["bytes"] <= 10 * d * d * 4

"""Dispatch-side buffer donation for the fused-round runners.

`run_until` re-dispatches the carried state every chunk; with
`donate_state` (its default) the state argument is donated to the jitted
dispatch so XLA writes the chunk's output state into the input's storage
instead of allocating a fresh replica per dispatch. These tests assert the
no-copy contract at both levels: the lowering carries the input→output
aliasing annotation, and at runtime the donated buffer is actually consumed
(deleted) — while `run_until` still shields the CALLER's init_state with
its single up-front defensive copy.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh
from repro.core.driver import IterativeSpec, make_iterative_runner, run_until
from repro.core.engine import identity_hash


def _counting_spec(halt_at: float | None = None) -> IterativeSpec:
    """Tiny 1-shard job: state is a running per-key sum (replicated)."""

    def map_fn(state, inputs, r):
        return inputs["k"], {"v": inputs["v"]}

    def reduce_fn(state, rk, rv, valid, r):
        seg = jnp.where(valid, rk, 0)
        add = jax.ops.segment_sum(jnp.where(valid, rv["v"], 0.0), seg,
                                  num_segments=state.shape[0])
        new_state = jax.lax.psum(add, "data") + state
        return new_state, {"total": jnp.sum(new_state)}

    halt_fn = None
    if halt_at is not None:
        def halt_fn(state, aux, r):
            return aux["total"] >= halt_at

    return IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn,
                         hash_fn=identity_hash, capacity=4, n_rounds=2,
                         halt_fn=halt_fn)


def _inputs():
    return {"k": jnp.asarray([0, 1, 2, 3], jnp.int32),
            "v": jnp.ones((4,), jnp.float32)}


def test_donating_runner_lowering_aliases_state():
    """The donated state arg must appear as an input/output alias in the
    lowered program — the trace-level proof that no copy is emitted."""
    mesh = make_mesh((1,), ("data",))
    spec = _counting_spec()
    inputs, state = _inputs(), jnp.zeros((4,), jnp.float32)
    donating = make_iterative_runner(spec, mesh, donate_state=True)
    plain = make_iterative_runner(spec, mesh, donate_state=False)
    txt = donating.jitted.lower(inputs, state, jnp.uint32(0)).as_text()
    assert "tf.aliasing_output" in txt
    txt_plain = plain.jitted.lower(inputs, state, jnp.uint32(0)).as_text()
    assert "tf.aliasing_output" not in txt_plain


def test_donating_runner_consumes_state_not_inputs():
    """Runtime proof of no-copy: the donated state buffer is DELETED by the
    dispatch (its storage was reused for the output), while the sharded
    inputs — reused across every chunk — survive untouched."""
    mesh = make_mesh((1,), ("data",))
    spec = _counting_spec()
    inputs = _inputs()
    runner = make_iterative_runner(spec, mesh, donate_state=True)
    state = jnp.zeros((4,), jnp.float32)
    out_state, aux, dropped = runner(inputs, state, 0)
    assert state.is_deleted(), "donated state arg must be consumed, not copied"
    assert not inputs["k"].is_deleted() and not inputs["v"].is_deleted()
    # chunk-loop shape: feeding the output back re-donates cleanly. (Do NOT
    # np.asarray(out_state) first — materializing the host value caches it
    # on the Array and masks the deletion flag this test reads.)
    out2, _, _ = runner(inputs, out_state, 2)
    assert out_state.is_deleted()
    np.testing.assert_array_equal(np.asarray(out2), np.full((4,), 4.0, np.float32))


def _sharded_state_spec(halt_at: float | None = None) -> IterativeSpec:
    """Mixed-tier state: 'big' is a resident P(axis) leaf, 'tot' replicated.
    Donation must alias BOTH — sharded leaves stay resident on their devices
    with zero copies between chunks (module docstring: DONATION)."""

    def map_fn(state, inputs, r):
        return inputs["k"], {"v": inputs["v"]}

    def reduce_fn(state, rk, rv, valid, r):
        got = jax.lax.psum(jnp.sum(jnp.where(valid, rv["v"], 0.0)), "data")
        return ({"big": state["big"] + got, "tot": state["tot"] + got},
                {"total": state["tot"] + got})

    halt_fn = None
    if halt_at is not None:
        def halt_fn(state, aux, r):
            return aux["total"] >= halt_at

    return IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn,
                         hash_fn=identity_hash, capacity=4, n_rounds=2,
                         halt_fn=halt_fn,
                         state_specs={"big": P("data"), "tot": P()})


def _sharded_state():
    return {"big": jnp.zeros((1, 8), jnp.float32), "tot": jnp.float32(0.0)}


def test_donation_consumes_sharded_state_leaves():
    """Donation is layout-agnostic: a P(axis) carried leaf is aliased in the
    lowering and consumed at runtime exactly like a replicated one."""
    mesh = make_mesh((1,), ("data",))
    spec = _sharded_state_spec()
    inputs = _inputs()
    runner = make_iterative_runner(spec, mesh, donate_state=True)
    state = _sharded_state()
    txt = runner.jitted.lower(inputs, state, jnp.uint32(0)).as_text()
    assert txt.count("tf.aliasing_output") >= 2  # both leaves aliased
    out_state, aux, dropped = runner(inputs, state, 0)
    assert state["big"].is_deleted() and state["tot"].is_deleted()
    # chunk-loop shape: the output re-donates cleanly, sharded leaf included
    out2, _, _ = runner(inputs, out_state, 2)
    assert out_state["big"].is_deleted() and out_state["tot"].is_deleted()
    np.testing.assert_array_equal(np.asarray(out2["big"]),
                                  np.full((1, 8), 16.0, np.float32))


def test_run_until_donates_sharded_state_but_preserves_callers():
    """run_until's chunk loop with a sharded leaf: the caller's init_state
    survives, and donating matches the non-donating path bit for bit."""
    mesh = make_mesh((1,), ("data",))
    spec = _sharded_state_spec(halt_at=7.5)
    inputs = _inputs()
    init = _sharded_state()
    res = run_until(spec, inputs, init, mesh, max_rounds=8, min_chunk=1)
    assert not init["big"].is_deleted() and not init["tot"].is_deleted()
    assert res.halted and res.rounds_executed == 2
    ref = run_until(spec, inputs, init, mesh, max_rounds=8, min_chunk=1,
                    donate_state=False)
    np.testing.assert_array_equal(np.asarray(res.state["big"]),
                                  np.asarray(ref.state["big"]))
    np.testing.assert_array_equal(np.asarray(res.state["tot"]),
                                  np.asarray(ref.state["tot"]))


def test_run_until_donates_but_preserves_callers_state():
    """run_until donates every chunk's state internally (one defensive copy
    up front) — the caller's init_state must remain live and unchanged, and
    results must match the non-donating path bit for bit."""
    mesh = make_mesh((1,), ("data",))
    spec = _counting_spec(halt_at=7.5)
    inputs = _inputs()
    init = jnp.zeros((4,), jnp.float32)
    res = run_until(spec, inputs, init, mesh, max_rounds=8, min_chunk=1)
    assert not init.is_deleted()
    np.testing.assert_array_equal(np.asarray(init), np.zeros((4,), np.float32))
    assert res.halted and res.rounds_executed == 2  # totals 4.0 then 8.0
    ref = run_until(spec, inputs, init, mesh, max_rounds=8, min_chunk=1,
                    donate_state=False)
    np.testing.assert_array_equal(np.asarray(res.state), np.asarray(ref.state))
    np.testing.assert_array_equal(np.asarray(res.aux["total"]),
                                  np.asarray(ref.aux["total"]))

"""Differential tests: 'pallas' vs 'jnp' secure-shuffle keystream backends.

The secure shuffle's counter-space layout (nonce word 0 ^= source id, word 1
^= round id, absolute per-row counter starts) is computed identically by the
Pallas rows kernel and the vmapped pure-jnp oracle, so the two backends must
be BIT-exact — across nonce ids, counter rows, round ids, leaf wire dtypes
(u32/i32/f32/bf16), and odd word counts. These tests are what make swapping
crypto backends safe: any divergence is a key/nonce/counter layout bug, not
a tolerance issue, hence `assert_array_equal` throughout.

Property tests use hypothesis when installed and the seeded deterministic
fallback from tests/conftest.py otherwise. RFC 8439 vectors anchor the new
`chacha20_xor_rows` entry point to the spec, not just to our own oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import make_mesh
from repro.core import shuffle
from repro.core.shuffle import (
    CHACHA_IMPL_ENV,
    SecureShuffleConfig,
    keyed_all_to_all,
    record_wire_bytes,
    resolve_chacha_impl,
)
from repro.crypto import chacha
from rfc_vectors import RFC_BLOCK_232, RFC_CIPHERTEXT, RFC_KEY, RFC_NONCE_232, RFC_NONCE_242, RFC_PLAINTEXT

try:
    from repro.kernels.chacha20 import ops
except ImportError as e:  # e.g. no Pallas frontend for this platform
    pytest.skip(f"Pallas chacha20 kernel unavailable: {e}", allow_module_level=True)

KW = chacha.key_to_words(bytes(range(32)))
NW = chacha.nonce_to_words(b"\x07" * 12)


def _cfg(impl: str, counter0: int = 100) -> SecureShuffleConfig:
    return SecureShuffleConfig(key_words=KW, nonce_words=NW, counter0=counter0,
                               impl=impl)


# --- chacha20_xor_rows: pallas vs jnp, property-driven ------------------------


# Fixed shape set (jit caches per shape; examples then only vary data):
# single word, one exact block, odd tail mid-block, multi-block odd tail.
_ROW_SHAPES = [(1, 1), (3, 16), (4, 49), (7, 100)]


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_xor_rows_bitexact_across_impls(seed):
    """Random rows/ids/counters (incl. odd n_words): identical ciphertext."""
    rng = np.random.default_rng(seed)
    state0 = ops.make_state0(KW, NW, 0)
    for r, n_words in _ROW_SHAPES:
        words = jnp.asarray(rng.integers(0, 2**32, (r, n_words), dtype=np.uint32))
        nonce_ids = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
        ctr_starts = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
        got = ops.chacha20_xor_rows(words, state0, nonce_ids, ctr_starts,
                                    impl="pallas", interpret=True)
        want = ops.chacha20_xor_rows(words, state0, nonce_ids, ctr_starts, impl="jnp")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**32 - 1))
def test_keystream_rows_bitexact_across_impls_and_rounds(seed, round_id):
    """`shuffle._keystream_rows` draws the same bits under every impl, for
    arbitrary round ids — and round None is round 0."""
    rng = np.random.default_rng(seed)
    r, blocks = 4, 3
    n_words = blocks * 16 - 7  # odd tail: keystream truncation must agree
    nonce_ids = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
    ctr_rows = jnp.asarray(rng.integers(0, 2**16, (r,), dtype=np.uint32))
    out = {}
    for impl in ("pallas-interpret", "jnp"):
        cfg = _cfg(impl)
        out[impl] = np.asarray(shuffle._keystream_rows(
            cfg, nonce_ids, ctr_rows, jnp.uint32(cfg.counter0), blocks, n_words,
            jnp.uint32(round_id)))
    np.testing.assert_array_equal(out["pallas-interpret"], out["jnp"])

    a = shuffle._keystream_rows(_cfg("pallas-interpret"), nonce_ids, ctr_rows,
                                jnp.uint32(100), blocks, n_words, None)
    b = shuffle._keystream_rows(_cfg("jnp"), nonce_ids, ctr_rows,
                                jnp.uint32(100), blocks, n_words, jnp.uint32(0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_crypt_wires_bitexact_across_impls_all_dtypes(seed):
    """Full wire path (pack -> encrypt) over u32/i32/f32/bf16 leaves, odd
    row word counts included: identical ciphertext, and the jnp oracle
    decrypts what the pallas path encrypted."""
    rng = np.random.default_rng(seed)
    r, c = 3, 5  # odd c: bf16 rows pack to a half-word tail
    tree = {
        "k": jnp.asarray(rng.integers(-5, 100, (r, c)), jnp.int32),
        "f": jnp.asarray(rng.normal(size=(r, c, 3)).astype(np.float32)),
        "h": jnp.asarray(rng.normal(size=(r, c)).astype(np.float32)).astype(jnp.bfloat16),
        "u": jnp.asarray(rng.integers(0, 2**32, (r, c), dtype=np.uint32)),
    }
    wires, meta, treedef = shuffle._pack_wire(tree)
    nonce_ids = jnp.asarray(rng.integers(0, 2**32, (r,), dtype=np.uint32))
    ctr_rows = jnp.asarray(rng.integers(0, 2**16, (r,), dtype=np.uint32))
    round_id = jnp.uint32(rng.integers(0, 2**32))

    enc_p = shuffle._crypt_wires(wires, meta, _cfg("pallas-interpret"),
                                 nonce_ids, ctr_rows, round_id)
    enc_j = shuffle._crypt_wires(wires, meta, _cfg("jnp"),
                                 nonce_ids, ctr_rows, round_id)
    for a, b in zip(enc_p, enc_j):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # cross-impl roundtrip: jnp decrypts pallas ciphertext to the exact bits
    dec = shuffle._crypt_wires(enc_p, meta, _cfg("jnp"), nonce_ids, ctr_rows, round_id)
    back = shuffle._unpack_wire(dec, meta, treedef)
    for leaf, orig in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(
            np.asarray(leaf).view(np.uint8), np.asarray(orig).view(np.uint8))


# --- RFC 8439 anchors ---------------------------------------------------------


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_rfc_block_through_rows_entry_point(impl):
    """§2.3.2 keystream block via chacha20_xor_rows (XOR with zeros)."""
    state0 = ops.make_state0(chacha.key_to_words(RFC_KEY),
                             chacha.nonce_to_words(RFC_NONCE_232), 0)
    zeros = jnp.zeros((1, 16), jnp.uint32)
    ks = ops.chacha20_xor_rows(zeros, state0, jnp.zeros((1,), jnp.uint32),
                               jnp.ones((1,), jnp.uint32), impl=impl, interpret=True)
    np.testing.assert_array_equal(np.asarray(ks)[0], RFC_BLOCK_232)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_rfc_encrypt_through_rows_entry_point(impl):
    """§2.4.2 sunscreen vector, plus the per-row nonce-XOR id contract:
    XORing id x into nonce word 0 == pre-XORing x into the base nonce."""
    n = len(RFC_PLAINTEXT)
    pt = np.frombuffer(RFC_PLAINTEXT + b"\x00" * ((-n) % 4), dtype="<u4")
    nw = chacha.nonce_to_words(RFC_NONCE_242)
    x = jnp.asarray(np.stack([pt, pt]))
    nid = np.uint32(0xDEADBEEF)
    state0 = ops.make_state0(chacha.key_to_words(RFC_KEY), nw, 0)
    state0_pre = ops.make_state0(chacha.key_to_words(RFC_KEY),
                                 nw ^ np.array([nid, 0, 0], np.uint32), 0)
    ct = ops.chacha20_xor_rows(x, state0, jnp.asarray([0, nid], jnp.uint32),
                               jnp.asarray([1, 1], jnp.uint32), impl=impl,
                               interpret=True)
    assert np.asarray(ct)[0].tobytes()[:n] == RFC_CIPHERTEXT
    ct_pre = ops.chacha20_xor_rows(x[1:], state0_pre, jnp.zeros((1,), jnp.uint32),
                                   jnp.ones((1,), jnp.uint32), impl=impl,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(ct)[1], np.asarray(ct_pre)[0])


# --- impl selection -----------------------------------------------------------


def test_impl_resolution_env_and_explicit(monkeypatch, no_calibration):
    monkeypatch.delenv(CHACHA_IMPL_ENV, raising=False)
    assert resolve_chacha_impl("auto")[0] == "pallas"
    assert resolve_chacha_impl("jnp") == ("jnp", True)
    assert resolve_chacha_impl("pallas-interpret") == ("pallas", True)

    monkeypatch.setenv(CHACHA_IMPL_ENV, "jnp")
    assert resolve_chacha_impl("auto") == ("jnp", True)
    # an explicit impl always wins over the environment
    assert resolve_chacha_impl("pallas-interpret") == ("pallas", True)

    monkeypatch.setenv(CHACHA_IMPL_ENV, "pallas-interpret")
    assert resolve_chacha_impl("auto") == ("pallas", True)

    with pytest.raises(ValueError):
        resolve_chacha_impl("vulkan")


def test_invalid_env_impl_error_names_the_env_var(monkeypatch):
    """A bad $REPRO_CHACHA_IMPL must be called out as coming from the
    environment (a generic message sends users hunting through code for a
    value they never passed)."""
    monkeypatch.setenv(CHACHA_IMPL_ENV, "vulkan")
    with pytest.raises(ValueError, match=rf"\${CHACHA_IMPL_ENV}='vulkan'"):
        resolve_chacha_impl("auto")
    # env value 'auto' is also invalid (it cannot self-resolve) and env-blamed
    monkeypatch.setenv(CHACHA_IMPL_ENV, "auto")
    with pytest.raises(ValueError, match=rf"\${CHACHA_IMPL_ENV}"):
        resolve_chacha_impl("auto")
    # an explicit bad impl is NOT blamed on the environment
    monkeypatch.delenv(CHACHA_IMPL_ENV, raising=False)
    with pytest.raises(ValueError) as ei:
        resolve_chacha_impl("vulkan")
    assert CHACHA_IMPL_ENV not in str(ei.value)


def test_with_impl_override():
    cfg = _cfg("auto")
    assert cfg.with_impl(None) is cfg
    assert cfg.with_impl("auto") is cfg
    over = cfg.with_impl("jnp")
    assert over.impl == "jnp" and over.counter0 == cfg.counter0
    assert cfg.impl == "auto"  # frozen: original untouched


# --- wire accounting: CTR ciphertext expansion is zero ------------------------


def test_wire_bytes_secure_equals_plain():
    """The secure wire form (packed u32 words) carries exactly the plaintext
    byte count for 4-byte leaf dtypes — CTR adds no ciphertext expansion."""
    mesh = make_mesh((1,), ("data",))
    tree = {
        "k": jnp.arange(8, dtype=jnp.int32).reshape(1, 8),
        "v": jnp.ones((1, 8, 2), jnp.float32),
    }
    specs = compat.tree_map(lambda _: P("data"), tree)

    def run(secure):
        body = lambda t: keyed_all_to_all(t, "data", secure)
        fn = compat.shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs,
                              check_vma=False)
        return jax.jit(fn)(tree)

    with record_wire_bytes() as recs:
        out_plain = run(None)
        out_sec = run(_cfg("pallas"))
    assert len(recs) == 2
    plain, sec = recs
    assert plain["secure"] is False and sec["secure"] is True
    assert plain["bytes"] == sec["bytes"] == 8 * 4 + 8 * 2 * 4
    # and the encrypted exchange is transparent end to end
    for a, b in zip(jax.tree.leaves(out_sec), jax.tree.leaves(out_plain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- multi-round driver: fused secure k-means identical under both impls ------


@pytest.mark.slow
def test_secure_kmeans_multiround_bitexact_across_impls():
    """Acceptance anchor: a fused multi-round secure k-means run produces
    bit-identical centers/shifts whether the shuffle keystream comes from the
    Pallas rows kernel or the jnp oracle (exercises the `chacha_impl`
    plumbing through driver entry points)."""
    from repro.core.driver import run_iterative_mapreduce
    from repro.core.kmeans import generate_points, make_kmeans_iterative_spec

    mesh = make_mesh((1,), ("data",))
    pts, _ = generate_points(256, 4, seed=5)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((256,), jnp.float32)}
    spec = make_kmeans_iterative_spec(4, 1, n_rounds=2)
    c0 = jnp.asarray(pts[:4])
    out = {}
    for impl in ("pallas", "jnp"):
        final, aux, dropped = run_iterative_mapreduce(
            spec, inputs, c0, mesh, secure=_cfg("auto"), chacha_impl=impl)
        assert int(np.asarray(dropped).sum()) == 0
        out[impl] = (np.asarray(final), np.asarray(aux["shift"]),
                     np.asarray(aux["centers"]))
    for a, b in zip(out["pallas"], out["jnp"]):
        np.testing.assert_array_equal(a, b)

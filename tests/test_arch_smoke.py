"""Per-arch smoke tests: reduced config, forward + one grad step on CPU.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); here every family runs for real at toy scale.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import forward, init_params, loss_fn, param_axes

B, T = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    if cfg.family == "moe":
        assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_grad_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.key(2))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gn = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    )
    assert np.isfinite(float(gn)) and float(gn) > 0
    # a small gradient step reduces loss on the same batch (lr kept small:
    # large steps flip discrete MoE routing decisions)
    lr = 0.05
    p2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2, _ = loss_fn(cfg, p2, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_structure_matches(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    axes = param_axes(cfg)
    pl = jax.tree.leaves(params)
    al = jax.tree.leaves(
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )
    assert len(pl) == len(al), (len(pl), len(al))
    for p, a in zip(pl, al):
        assert len(a) == p.ndim, (a, p.shape)


def test_full_configs_match_assignment():
    """Exact numbers from the assignment block."""
    c = get_config("granite-moe-3b-a800m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        32, 1536, 24, 8, 512, 49155) and (c.n_experts, c.n_experts_per_tok) == (40, 8)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        24, 2048, 16, 16, 1408, 151936) and (c.n_experts, c.n_experts_per_tok,
                                             c.n_shared_experts) == (60, 4, 4)
    c = get_config("whisper-base")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (6, 512, 8, 2048, 51865)
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        88, 12288, 96, 8, 28672, 32768)
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        95, 8192, 64, 8, 22016, 102400)
    c = get_config("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        40, 4096, 32, 2, 13696, 151552)
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        52, 6144, 48, 1, 24576, 49152)
    c = get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size,
            c.ssm_state) == (38, 2048, 32, 32, 8192, 32000, 64)
    c = get_config("chameleon-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        48, 8192, 64, 8, 22016, 65536)
    c = get_config("rwkv6-1.6b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (24, 2048, 7168, 65536)

"""Persistent job service (`serve/service.py`): bucket ladder, runner-cache
counters + LRU eviction, env knob resolvers, warm-resubmit zero compiles,
interleaved == serial bit-identity at queue depth > 1, re-entrant wire
accounting across interleaved generators, admission-sim policy comparison."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.driver import IterativeSpec, run_until_chunks
from repro.core.engine import identity_hash
from repro.core.shuffle import (
    SecureShuffleConfig,
    record_wire_bytes,
    wire_accounting,
)
from repro.crypto import chacha
from repro.runtime.sim import AdmissionSim, SimJob, burst_trace, straggler_trace
from repro.serve.service import (
    BUCKET_GROWTH_ENV,
    MAX_RUNNERS_ENV,
    RunnerCache,
    SecureJobService,
    bucket_for,
    resolve_bucket_growth,
    resolve_max_resident,
)


def _mesh1():
    return make_mesh((1,), ("data",))


def _secure_cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x21" * 12),
        counter0=3,
    )


# one cache for every secure test in this module: the whole point of the
# service is that compiled programs amortize across jobs AND sessions
_SECURE_CACHE = RunnerCache()


# --- geometric bucket ladder --------------------------------------------------


def test_bucket_ladder_properties():
    """Rungs are >= n, aligned to `multiple`, and depend only on
    (multiple, growth) — every size in a rung's span shares the rung."""
    for n, want in [(1, 4), (4, 4), (5, 8), (9, 16), (17, 32), (100, 128)]:
        assert bucket_for(n, multiple=4, growth=2.0) == want
    # alignment + cover, across growth factors
    for growth in (1.5, 2.0, 4.0):
        for n in range(1, 200):
            b = bucket_for(n, multiple=8, growth=growth)
            assert b >= n and b % 8 == 0
    # the ladder is strictly increasing even when growth barely clears
    # the alignment unit (growth * multiple rounds back to multiple)
    assert bucket_for(9, multiple=8, growth=1.01) == 16
    # 1.1x a compiled size lands on the SAME rung (the reuse contract)
    assert bucket_for(110, growth=2.0) == bucket_for(100, growth=2.0) == 128
    with pytest.raises(ValueError, match="n >= 1"):
        bucket_for(0)
    with pytest.raises(ValueError, match="multiple >= 1"):
        bucket_for(4, multiple=0)


def test_bucket_growth_resolver_env(monkeypatch, no_calibration):
    monkeypatch.delenv(BUCKET_GROWTH_ENV, raising=False)
    assert resolve_bucket_growth() == 2.0
    assert resolve_bucket_growth(1.5) == 1.5
    monkeypatch.setenv(BUCKET_GROWTH_ENV, "1.25")
    assert resolve_bucket_growth("auto") == 1.25
    # an explicit value always wins over the environment
    assert resolve_bucket_growth(4.0) == 4.0
    # a bad ENV value must blame the env var by name
    monkeypatch.setenv(BUCKET_GROWTH_ENV, "spam")
    with pytest.raises(ValueError, match=r"\$REPRO_BUCKET_GROWTH"):
        resolve_bucket_growth("auto")
    monkeypatch.setenv(BUCKET_GROWTH_ENV, "1.0")
    with pytest.raises(ValueError, match=r"\$REPRO_BUCKET_GROWTH"):
        resolve_bucket_growth(None)
    # a bad EXPLICIT value must NOT blame the environment
    monkeypatch.delenv(BUCKET_GROWTH_ENV, raising=False)
    with pytest.raises(ValueError) as ei:
        resolve_bucket_growth(0.5)
    assert "$" not in str(ei.value)


def test_max_resident_resolver_env(monkeypatch, no_calibration):
    monkeypatch.delenv(MAX_RUNNERS_ENV, raising=False)
    assert resolve_max_resident("auto") is None
    assert resolve_max_resident(None) is None
    assert resolve_max_resident(3) == 3
    for unbounded in ("none", "0", "unbounded"):
        monkeypatch.setenv(MAX_RUNNERS_ENV, unbounded)
        assert resolve_max_resident("auto") is None
    monkeypatch.setenv(MAX_RUNNERS_ENV, "2")
    assert resolve_max_resident("auto") == 2
    monkeypatch.setenv(MAX_RUNNERS_ENV, "-3")
    with pytest.raises(ValueError, match=r"\$REPRO_SERVICE_MAX_RUNNERS"):
        resolve_max_resident("auto")
    monkeypatch.delenv(MAX_RUNNERS_ENV, raising=False)
    with pytest.raises(ValueError) as ei:
        resolve_max_resident(-1)
    assert "$" not in str(ei.value)


# --- runner cache -------------------------------------------------------------


def test_runner_cache_counters_and_lru_eviction():
    cache = RunnerCache(max_resident=2)

    def dead():  # a hit must never invoke the build closure
        raise AssertionError("build called on a cache hit")

    assert cache.get_or_build(("a",), lambda: "A") == "A"   # miss
    assert cache.get_or_build(("a",), dead) == "A"          # hit
    assert cache.get_or_build(("b",), lambda: "B") == "B"   # miss
    assert cache.get_or_build(("a",), dead) == "A"          # hit: a now MRU
    assert cache.get_or_build(("c",), lambda: "C") == "C"   # miss: evicts b
    assert cache.keys() == [("a",), ("c",)]
    s = cache.stats()
    assert (s["hits"], s["misses"], s["evictions"]) == (2, 3, 1)
    assert s["resident"] == 2 and s["max_resident"] == 2
    # the evicted entry is rebuilt on next request (a fresh miss)
    assert cache.get_or_build(("b",), lambda: "B2") == "B2"
    assert cache.stats()["misses"] == 4
    cache.clear()
    assert len(cache) == 0


def test_cache_view_keys_disjoint_across_secure_material():
    """Key/nonce/counter material is baked into traced closures, so it must
    key the cache: different material can never alias a runner."""
    cache = RunnerCache()
    mesh = _mesh1()

    def view(secure):
        return cache.view(spec_id=("w", 1), mesh=mesh, axis_name="data",
                          secure=secure)

    cfg = _secure_cfg()
    bases = [
        view(None).key_base,
        view(cfg).key_base,
        view(SecureShuffleConfig(key_words=chacha.key_to_words(b"\x07" * 32),
                                 nonce_words=cfg.nonce_words,
                                 counter0=cfg.counter0)).key_base,
        view(SecureShuffleConfig(key_words=cfg.key_words,
                                 nonce_words=cfg.nonce_words,
                                 counter0=cfg.counter0 + 1)).key_base,
    ]
    assert len(set(bases)) == len(bases)
    # identical material resolves to the identical base (shareable)
    assert view(_secure_cfg()).key_base == bases[1]
    # distinct workload identity splits the base too
    assert cache.view(spec_id=("w", 2), mesh=mesh,
                      axis_name="data").key_base != bases[0]


# --- service: warm resubmits -------------------------------------------------


def test_service_warm_resubmit_zero_compiles():
    """A same-bucket resubmit runs entirely on cached programs: zero runner
    misses AND zero new XLA compile-cache entries, with the second job's
    keystream budget reserved right after the first's."""
    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal(-3, 0.1, (6, 2)),
                          rng.normal(3, 0.1, (6, 2))]).astype(np.float32)
    cache = RunnerCache()
    with SecureJobService(_mesh1(), cache=cache, max_concurrent=2) as svc:
        # min_chunk == max_chunk: every dispatch uses ONE chunk size, so
        # the warm claim cannot hinge on matching convergence trajectories
        h1 = svc.submit_kmeans(pts, 2, max_rounds=4, min_chunk=4, max_chunk=4)
        r1 = h1.result(timeout=300)
        assert h1.runner_misses > 0 and not h1.warm
        assert r1["halted"] and r1["n_iter"] >= 1
        assert h1.latency_s is not None and h1.queue_s is not None

        compiles_before = cache.compile_cache_size()
        h2 = svc.submit_kmeans(pts[:10], 2, max_rounds=4,
                               min_chunk=4, max_chunk=4)
        r2 = h2.result(timeout=300)
        assert h2.runner_misses == 0 and h2.warm
        assert cache.compile_cache_size() == compiles_before
        # n=10 and n=12 pad to the same geometric bucket
        assert h2.bucket == h1.bucket
        # disjoint keystream budgets: monotone round-base reservation
        assert h1.round_base == 0
        assert h2.round_base == h1.round_base + h1.max_rounds
        assert r2["halted"]
    assert svc.stats()["jobs_completed"] == 2


def test_submit_validation_and_closed_service():
    svc = SecureJobService(_mesh1())
    with pytest.raises(ValueError, match="k must be"):
        svc.submit_kmeans(np.zeros((4, 2), np.float32), 9)
    with pytest.raises(ValueError, match="values must be"):
        svc.submit_sort(np.zeros((0,), np.float32))
    with pytest.raises(ValueError, match="n_rounds must be"):
        svc.submit_grep(np.zeros((4,), np.int32), [1], n_rounds=0)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_grep(np.zeros((4,), np.int32), [1])


# --- two-level priority admission ---------------------------------------------


def test_priority_submit_admits_ahead_of_fifo():
    """With one slot busy, a later priority submit is admitted before the
    earlier normal one; the active job is never preempted."""
    import time as _time

    toks = (np.arange(16) % 5).astype(np.int32)
    with SecureJobService(_mesh1(), max_concurrent=1) as svc:
        with pytest.raises(ValueError, match="priority"):
            svc.submit_grep(toks, [1], priority=-1)
        ha = svc.submit_grep(toks, [1], n_rounds=2)          # fills the slot
        # wait until A OWNS the slot (admitted, compiling its runner) so B
        # and C verifiably queue behind it
        deadline = _time.perf_counter() + 120
        while ha.started_at is None:
            assert _time.perf_counter() < deadline, "job A never started"
            _time.sleep(0.001)
        hb = svc.submit_grep(toks, [2], n_rounds=2)          # queues (normal)
        hc = svc.submit_grep(toks, [3], n_rounds=2, priority=1)  # jumps queue
        for h in (ha, hb, hc):
            h.result(timeout=600)
    assert (ha.priority, hb.priority, hc.priority) == (0, 0, 1)
    # A kept its slot (admission order, not preemption)...
    assert ha.started_at < hc.started_at
    # ...and C was admitted ahead of the earlier-submitted B
    assert hc.started_at < hb.started_at
    assert hc.finished_at < hb.started_at  # one slot: strictly serial
    # keystream budgets still reserve in SUBMIT order (disjointness is
    # assigned at submit time, independent of admission order)
    assert hb.round_base == ha.round_base + ha.max_rounds
    assert hc.round_base == hb.round_base + hb.max_rounds


def test_admission_sim_priority_mirrors_service():
    """The sim's two-level admission: a priority job among the arrived
    prefix admits first; a priority job that has NOT arrived yet changes
    nothing; total work (makespan) is unchanged either way."""
    from dataclasses import replace as dc_replace

    sim = AdmissionSim(max_concurrent=1, min_chunk=8, max_chunk=8)
    jobs = [SimJob(0.0, 4096, 8), SimJob(0.0, 4096, 8),
            SimJob(0.0, 4096, 8, priority=1)]
    flat = [dc_replace(j, priority=0) for j in jobs]
    r, r_flat = sim.run(jobs, "bucketed"), sim.run(flat, "bucketed")
    lat, lat_flat = r["per_job_latency_s"], r_flat["per_job_latency_s"]
    # the priority job cut ahead of both normal jobs...
    assert lat[2] < lat[0] < lat[1]
    assert lat[2] < lat_flat[2]
    # ...without creating or destroying work
    assert r["makespan_s"] == pytest.approx(r_flat["makespan_s"])

    # a priority job arriving after the queue drains cannot jump anything:
    # identical replay to the all-normal trace
    late = [SimJob(0.0, 4096, 8), SimJob(0.0, 4096, 8),
            SimJob(1e6, 4096, 8, priority=1)]
    late_flat = [dc_replace(j, priority=0) for j in late]
    assert sim.run(late, "bucketed") == sim.run(late_flat, "bucketed")


# --- LRU eviction under interleaved live jobs ---------------------------------


def test_lru_eviction_of_live_jobs_runner_is_bitidentical():
    """Residency cap 1 + two interleaved jobs: every scheduler pass evicts
    the OTHER live job's runner, which is rebuilt (a fresh miss) on its next
    chunk. Results must be bit-identical to an unbounded cache — eviction
    costs recompiles, never correctness (round offsets, carried state, and
    keystream ranges live outside the evicted program)."""
    rng = np.random.default_rng(5)
    toks = rng.integers(0, 7, (24,)).astype(np.int32)

    def run(cache):
        with SecureJobService(_mesh1(), secure=_secure_cfg(), cache=cache,
                              max_concurrent=2) as svc:
            # different pattern sets -> different cache keys; fixed chunk
            # size 1 -> each job needs its runner on every pass
            ha = svc.submit_grep(toks, [1, 2], n_rounds=2,
                                 min_chunk=1, max_chunk=1)
            hb = svc.submit_grep(toks, [3, 4, 5], n_rounds=2,
                                 min_chunk=1, max_chunk=1)
            return ha.result(timeout=600), hb.result(timeout=600)

    capped = RunnerCache(max_resident=1)
    ra_c, rb_c = run(capped)
    s = capped.stats()
    assert s["max_resident"] == 1 and s["resident"] <= 1
    # both jobs LIVE while their runners thrash: at least one eviction per
    # extra rebuild, and every post-eviction chunk re-misses
    assert s["evictions"] >= 2
    assert s["misses"] >= 4

    unbounded = RunnerCache()
    ra_u, rb_u = run(unbounded)
    assert unbounded.stats()["evictions"] == 0
    assert unbounded.stats()["misses"] == 2  # one compile per job, then hits
    for a, b in [(ra_c, ra_u), (rb_c, rb_u)]:
        assert a.keys() == b.keys()
        for key in a:
            np.testing.assert_array_equal(np.asarray(a[key]),
                                          np.asarray(b[key]), err_msg=key)


# --- service: interleaved vs serial (queue depth > 1) ------------------------


def _submit_three(svc, pts, vals, toks, pats):
    """The fixed submission order both runs share (same round bases)."""
    hk = svc.submit_kmeans(pts, 2, max_rounds=6, min_chunk=2, max_chunk=2)
    hs = svc.submit_sort(vals, max_rounds=3, min_chunk=1, max_chunk=2)
    hg = svc.submit_grep(toks, pats, n_rounds=2)
    return hk, hs, hg


def test_interleaved_bitidentical_to_serial_secure():
    """Three concurrent SECURE jobs whose chunk dispatches interleave on one
    mesh produce bit-identical results to the same submissions run one at a
    time — per-job round bases keep every keystream range disjoint, and each
    suspended generator owns its carried state."""
    rng = np.random.default_rng(7)
    pts = np.concatenate([rng.normal(-2, 0.2, (5, 2)),
                          rng.normal(2, 0.2, (5, 2))]).astype(np.float32)
    vals = rng.normal(0, 1, (9,)).astype(np.float32)
    toks = rng.integers(0, 5, (12,)).astype(np.int32)
    pats = np.array([1, 3], np.int32)

    def run(max_concurrent):
        with SecureJobService(_mesh1(), secure=_secure_cfg(),
                              cache=_SECURE_CACHE,
                              max_concurrent=max_concurrent) as svc:
            handles = _submit_three(svc, pts, vals, toks, pats)
            results = [h.result(timeout=600) for h in handles]
        return handles, results

    (hk, hs, hg), (rk, rs, rg) = run(max_concurrent=3)  # interleaved
    # depth 3 really interleaved: kmeans spans multiple scheduler passes
    assert hk.chunks > 1
    # the grep job was admitted at a NONZERO round base...
    assert hg.round_base == hk.max_rounds + hs.max_rounds
    # ...and still counts exactly like the host oracle (cursor-in-state:
    # the stream position is offset-agnostic; -1 bucket padding is inert)
    np.testing.assert_array_equal(
        rg["counts"], np.array([(toks == p).sum() for p in pats], np.float32))
    np.testing.assert_array_equal(np.sort(rs["sorted"]), np.sort(vals))

    (hk2, hs2, hg2), (rk2, rs2, rg2) = run(max_concurrent=1)  # serial
    for a, b in [(rk, rk2), (rs, rs2), (rg, rg2)]:
        assert a.keys() == b.keys()
        for key in a:
            np.testing.assert_array_equal(np.asarray(a[key]),
                                          np.asarray(b[key]), err_msg=key)
    # fresh service, shared cache: the serial rerun compiled NOTHING
    assert all(h.warm for h in (hk2, hs2, hg2))


# --- wire accounting: re-entrant across interleaved generators ----------------


def _tiny_spec(n=4):
    def map_fn(state, inputs, r):
        return jnp.zeros((n,), jnp.int32), {"v": jnp.ones((n,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        got = jax.lax.psum(jnp.sum(jnp.where(valid, rv["v"], 0.0)), "data")
        return state + got, {"got": got}

    return IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn,
                         hash_fn=identity_hash, capacity=n, n_rounds=1)


def _drain(gen):
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def test_wire_accounting_reentrant_interleaved_generators():
    """Two interleaved `run_until_chunks` jobs, each holding its own
    `record_wire_bytes` context open across suspensions: sinks are
    independent, records split by job tag, and contexts may exit OUT of
    stack order (the norm for generator-held contexts)."""
    mesh = _mesh1()
    inputs = {"x": jnp.zeros((4,), jnp.float32)}
    assert not wire_accounting.enabled

    ctx_a = record_wire_bytes()
    recs_a = ctx_a.__enter__()
    gen_a = run_until_chunks(_tiny_spec(), inputs, jnp.float32(0.0), mesh,
                             max_rounds=2, job_tag="job-A", runners={})
    next(gen_a)  # traces job A's runner: records land in the open sink(s)

    ctx_b = record_wire_bytes()
    recs_b = ctx_b.__enter__()
    gen_b = run_until_chunks(_tiny_spec(), inputs, jnp.float32(0.0), mesh,
                             max_rounds=2, job_tag="job-B", runners={})
    next(gen_b)  # traces job B's runner while BOTH sinks are open

    # out-of-LIFO exit: A entered first and leaves first, while B stays open
    ctx_a.__exit__(None, None, None)
    res_a = _drain(gen_a)
    res_b = _drain(gen_b)
    ctx_b.__exit__(None, None, None)

    # A's sink was open across BOTH traces — records split by job tag...
    assert {r["job"] for r in recs_a} == {"job-A", "job-B"}
    # ...while B's sink, opened after A's trace, holds only B's records
    assert {r["job"] for r in recs_b} == {"job-B"}
    # both jobs traced the SAME shuffle: byte-for-byte identical accounting
    by_job = lambda sink, tag: [r["bytes"] for r in sink if r["job"] == tag]
    assert by_job(recs_a, "job-A") == by_job(recs_a, "job-B") == by_job(
        recs_b, "job-B")
    assert all(r["bytes"] > 0 for r in recs_a)
    # interleaving didn't corrupt either job's actual result
    assert float(res_a.state) == float(res_b.state) == 2 * 4
    # the module-level stack is clean again
    assert not wire_accounting.enabled and not wire_accounting._sinks


def test_wire_accounting_shared_sink_splits_by_job_tag():
    """ONE outer sink spanning two interleaved jobs attributes every record
    to the job whose dispatch traced it."""
    mesh = _mesh1()
    inputs = {"x": jnp.zeros((4,), jnp.float32)}
    with record_wire_bytes() as recs:
        gen_a = run_until_chunks(_tiny_spec(), inputs, jnp.float32(0.0), mesh,
                                 max_rounds=1, job_tag=11, runners={})
        gen_b = run_until_chunks(_tiny_spec(), inputs, jnp.float32(0.0), mesh,
                                 max_rounds=1, job_tag=22, runners={})
        next(gen_a, None)
        next(gen_b, None)
        _drain(gen_a)
        _drain(gen_b)
    jobs = [r["job"] for r in recs]
    assert set(jobs) == {11, 22}
    assert jobs.index(11) < jobs.index(22)  # trace order preserved


# --- admission-policy testbed -------------------------------------------------


def test_admission_sim_bucketed_beats_compile_per_job():
    """On both canonical traces the bucketed policy wins virtual makespan
    (and compiles strictly less) than compile-per-job — the testbed claim
    the real service's bucket ladder rests on."""
    sim = AdmissionSim()
    for trace in (burst_trace(), straggler_trace()):
        bucketed = sim.run(trace, "bucketed")
        per_job = sim.run(trace, "compile-per-job")
        assert bucketed["makespan_s"] < per_job["makespan_s"]
        assert bucketed["compiles"] < per_job["compiles"]
        assert bucketed["mean_latency_s"] < per_job["mean_latency_s"]


def test_admission_sim_residency_cap_evicts():
    capped = AdmissionSim(max_resident=2)
    r = capped.run(burst_trace(), "bucketed")
    assert r["evictions"] > 0
    assert r["resident"] <= 2
    # the cap costs recompiles relative to the unbounded cache
    unbounded = AdmissionSim().run(burst_trace(), "bucketed")
    assert r["compiles"] >= unbounded["compiles"]

"""kmeans Pallas kernel vs pure-jnp oracle: shape/dtype sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.kmeans.ops import kmeans_assign
from repro.kernels.kmeans.ref import kmeans_assign_ref


@pytest.mark.parametrize(
    "n,d,k,tile",
    [
        (64, 2, 4, 16),
        (128, 8, 10, 32),
        (500, 2, 10, 128),  # padding path (500 % 128 != 0)
        (1024, 16, 50, 256),
        (77, 3, 7, 512),  # n < tile -> shrink
    ],
)
def test_kernel_matches_ref(n, d, k, tile):
    rng = np.random.default_rng(n + d + k)
    pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ctr = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    a_k, s_k, c_k = kmeans_assign(pts, ctr, impl="pallas", tile_n=tile, interpret=True)
    a_r, s_r, c_r = kmeans_assign_ref(pts, ctr)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-6)


def test_kernel_weights():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.normal(size=(96, 4)).astype(np.float32))
    ctr = jnp.asarray(rng.normal(size=(5, 4)).astype(np.float32))
    w = jnp.asarray((rng.random(96) > 0.3).astype(np.float32))
    a_k, s_k, c_k = kmeans_assign(pts, ctr, w, impl="pallas", tile_n=32, interpret=True)
    a_r, s_r, c_r = kmeans_assign_ref(pts, ctr, w)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-6)


def test_counts_sum_to_weight_total():
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.normal(size=(256, 2)).astype(np.float32))
    ctr = jnp.asarray(rng.normal(size=(10, 2)).astype(np.float32))
    _, _, c = kmeans_assign(pts, ctr, impl="pallas", tile_n=64, interpret=True)
    assert abs(float(jnp.sum(c)) - 256.0) < 1e-4

"""Calibrated cost model (`repro/perf/`): the no-calibration contract
(every `auto` resolver bit-for-bit on its historical default), synthetic-
calibration recommendations driving the resolvers, calibration persistence
and $REPRO_CALIBRATION activation, trace-driven prediction plumbing, and
the kernel padding model (`effective_blocks`)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import make_mesh
from repro.core.driver import (
    IterativeSpec,
    make_iterative_runner,
    resolve_capacity_factor,
    resolve_chunk_growth,
    resolve_halt_loop,
)
from repro.core.shuffle import (
    CHACHA_IMPL_ENV,
    SecureShuffleConfig,
    resolve_chacha_impl,
    resolve_coalesce,
)
from repro.crypto import chacha
from repro.perf.calibrate import (
    CALIBRATION_ENV,
    Calibration,
    effective_blocks,
    load_calibration,
    save_calibration,
)
from repro.perf.model import (
    CostModel,
    active_model,
    clear_active_model,
    recommendation,
    set_active_model,
    trace_workload,
)
from repro.serve.service import resolve_bucket_growth, resolve_max_resident


def _cal(*, pallas_block=0.001, jnp_block=0.002, launch_us=5.0,
         extra=None) -> Calibration:
    """A hand-built calibration with known constants (no probing)."""
    def entry(blk, resolved):
        return {"us_per_block": blk, "launch_us": launch_us,
                "compile_s": 8.0, "compile_eqns": 400, "resolved": resolved}

    return Calibration(
        backend="cpu", n_devices=1,
        chacha={"pallas": entry(pallas_block, ["pallas", True]),
                "jnp": entry(jnp_block, ["jnp", True])},
        all_to_all={"us_per_byte": 0.001, "base_us": 50.0},
        dispatch={"base_us": 100.0},
        round={"us_per_item": 0.01, "base_us": 200.0,
               "compile_s": 2.0, "compile_eqns": 150},
        compile={"s_per_eqn": 0.004, "base_s": 0.05},
        extra=extra or {},
    )


# --- the no-calibration contract ---------------------------------------------


def test_resolvers_keep_historical_defaults_without_calibration(no_calibration):
    """With no calibration active, every `auto` knob is its historical
    default — the strictly-additive contract the subsystem ships under."""
    assert active_model() is None
    assert recommendation("chacha_impl") is None
    assert resolve_chacha_impl("auto")[0] == "pallas"
    assert resolve_coalesce("auto") is True
    assert resolve_halt_loop(None) == "while"
    assert resolve_chunk_growth("auto") == 2
    assert resolve_capacity_factor() == 2.0
    assert resolve_bucket_growth() == 2.0
    assert resolve_max_resident("auto") is None


# --- synthetic model drives the resolvers ------------------------------------


def test_model_recommendations_drive_auto_resolvers(monkeypatch):
    monkeypatch.delenv(CHACHA_IMPL_ENV, raising=False)
    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    model = CostModel(_cal(jnp_block=0.0001, pallas_block=1.0))  # jnp cheapest
    set_active_model(model)
    try:
        assert model.recommend("chacha_impl") == "jnp"
        assert resolve_chacha_impl("auto") == ("jnp", True)
        # an explicit impl and the environment still BOTH outrank the model
        assert resolve_chacha_impl("pallas-interpret") == ("pallas", True)
        monkeypatch.setenv(CHACHA_IMPL_ENV, "pallas-interpret")
        assert resolve_chacha_impl("auto") == ("pallas", True)
        monkeypatch.delenv(CHACHA_IMPL_ENV, raising=False)

        # non-negative probed costs: coalesced wire + 'while' loop always win
        assert resolve_coalesce("auto") is True
        assert resolve_halt_loop(None) == "while"
        # the sim-backed knobs come from the model's candidate grids
        assert resolve_chunk_growth("auto") in (2, 3, 4)
        assert resolve_bucket_growth() in (1.5, 2.0, 4.0)
        # the model's 'unbounded' answer maps to the None cap
        assert model.recommend("max_resident") == "unbounded"
        assert resolve_max_resident("auto") is None
    finally:
        clear_active_model()


def test_capacity_factor_only_from_measured_extra():
    """No probe may shrink the overflow headroom: the model recommends a
    non-default capacity factor only when the calibration carries a
    deployment-measured one."""
    set_active_model(CostModel(_cal()))
    try:
        assert resolve_capacity_factor() == 2.0
    finally:
        clear_active_model()
    set_active_model(CostModel(_cal(extra={"capacity_factor": 3.5})))
    try:
        assert resolve_capacity_factor() == 3.5
    finally:
        clear_active_model()


def test_timing_model_prices_knob_vectors():
    """The per-vector TimingModel hooks hillclimb cell K relies on."""
    model = CostModel(_cal())
    base = model.timing_model()
    assert base.xla_compile_s == pytest.approx(8.0 + 2.0)
    assert model.timing_model(loop_impl="masked_scan").xla_compile_s == \
        pytest.approx(2 * base.xla_compile_s)
    assert model.timing_model(coalesce=False).net_latency_s == \
        pytest.approx(2 * base.net_latency_s)
    # impl selects the cipher probe's bandwidth
    fast = model.timing_model(impl="pallas")
    slow = model.timing_model(impl="jnp")
    assert fast.crypto_bw_bytes_s > slow.crypto_bw_bytes_s


# --- persistence + activation ------------------------------------------------


def test_save_load_roundtrip_keyed_by_backend(tmp_path):
    path = str(tmp_path / "calib.json")
    cal = _cal()
    save_calibration(cal, path)
    assert load_calibration(path, backend="cpu", n_devices=1) == cal
    # a calibration probed on a different shape never applies
    assert load_calibration(path, backend="tpu", n_devices=1) is None
    assert load_calibration(path, backend="cpu", n_devices=8) is None
    # a second entry merges instead of clobbering
    other = Calibration(**{**cal.to_dict(), "backend": "tpu", "n_devices": 8})
    save_calibration(other, path)
    assert load_calibration(path, backend="cpu", n_devices=1) == cal
    assert load_calibration(path, backend="tpu", n_devices=8) == other


def test_active_model_from_env(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    save_calibration(_cal(), str(path))
    monkeypatch.setenv(CALIBRATION_ENV, str(path))
    clear_active_model()
    try:
        model = active_model()
        assert isinstance(model, CostModel) and model.cal == _cal()
        assert recommendation("max_resident") == "unbounded"
        # explicit None FORCES the model off even with the env var set
        set_active_model(None)
        assert active_model() is None
    finally:
        clear_active_model()
    # unreadable / corrupt files resolve to no model, never an error
    monkeypatch.setenv(CALIBRATION_ENV, str(tmp_path / "missing.json"))
    assert active_model() is None
    bad = tmp_path / "bad.json"
    bad.write_text("not json")
    monkeypatch.setenv(CALIBRATION_ENV, str(bad))
    clear_active_model()
    try:
        assert active_model() is None
    finally:
        clear_active_model()


# --- trace-driven predictions ------------------------------------------------


def _runner(secure):
    mesh = make_mesh((1,), ("data",))

    def map_fn(state, inputs, r):
        keys = jnp.arange(inputs["x"].shape[0], dtype=jnp.int32) % 4
        return keys, {"x": inputs["x"]}

    def reduce_fn(state, keys, values, valid, r):
        s = jnp.sum(jnp.where(valid, values["x"], 0.0))
        return {"s": state["s"] + lax.psum(s, "data")}, {"s": s}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, n_rounds=2)
    return make_iterative_runner(spec, mesh, "data", secure=secure)


def test_trace_workload_reads_the_programs_own_wire():
    sec = SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x05" * 12))
    inputs = {"x": jnp.ones((16,), jnp.float32)}
    state = {"s": jnp.float32(0)}
    trace = trace_workload(_runner(sec), inputs, state,
                           n_shards=1, n_local_items=16)
    assert trace.secure and trace.coalesced
    assert trace.wire_bytes > 0 and trace.collectives >= 1
    # coalesced single wire: one encrypt + one decrypt launch per round
    assert trace.keystream_launches == 2
    assert trace.keystream_blocks > 0 and trace.blocks_per_launch_row >= 1
    assert trace.n_eqns > 0

    model = CostModel(_cal())
    assert model.predict_wire_bytes(trace) == trace.wire_bytes
    pred = model.predict_round_us(trace)
    assert pred > 0
    # a costlier cipher probe must predict a costlier secure round
    dearer = CostModel(_cal(pallas_block=10.0, jnp_block=20.0))
    assert dearer.predict_round_us(trace) > pred
    # compile prediction respects the plain-XLA floor
    floor = (model.cal.compile["base_s"]
             + trace.n_eqns * model.cal.compile["s_per_eqn"])
    assert model.predict_compile_s(trace) >= floor

    plain = trace_workload(_runner(None), inputs, state,
                           n_shards=1, n_local_items=16)
    assert not plain.secure and plain.keystream_launches == 0
    assert model.predict_round_us(plain) < pred


# --- kernel padding model ----------------------------------------------------


def test_effective_blocks_padding_rules():
    # jnp oracle: exactly the blocks the wire needs
    assert effective_blocks(4, 3, "jnp", True) == 12
    # interpret-mode pallas: rows^2 x blocks padded to an 8-multiple (min 8)
    assert effective_blocks(1, 1, "pallas", True) == 8
    assert effective_blocks(1, 9, "pallas", True) == 16
    assert effective_blocks(8, 3, "pallas", True) == 8 * 8 * 8
    # compiled pallas: rows x full 128-lane VREG multiples
    assert effective_blocks(2, 1, "pallas", False) == 2 * 128
    assert effective_blocks(2, 130, "pallas", False) == 2 * 256
    # degenerate launches cost nothing
    assert effective_blocks(0, 4, "pallas", True) == 0
    assert effective_blocks(4, 0, "jnp", False) == 0

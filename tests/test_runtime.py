"""Cluster runtime: protocol, fault tolerance, stragglers, attestation."""

import numpy as np
import pytest

from repro.core.kmeans import generate_points, kmeans_step_ref
from repro.runtime.jobs import (
    KMEANS_MAP,
    KMEANS_REDUCE,
    make_cluster,
    run_kmeans,
    run_wordcount,
)
from repro.runtime.node import MapReduceJob, SecurityPolicy

LINES = [
    "the quick brown fox jumps over the lazy dog",
    "the dog barks",
    "a quick fox",
    "lazy lazy dog",
] * 4


def _expected_counts(lines):
    want = {}
    for ln in lines:
        for w in ln.split():
            want[w] = want.get(w, 0) + 1
    return want


@pytest.mark.parametrize(
    "policy",
    [
        SecurityPolicy(encryption=True, enclave=True),
        SecurityPolicy(encryption=False, enclave=False),
    ],
)
def test_wordcount_end_to_end(policy):
    cluster, client, _ = make_cluster(8, policy=policy)
    counts, info = run_wordcount(cluster, client, LINES, n_mappers=5, n_reducers=3)
    assert counts == _expected_counts(LINES)
    assert info["elapsed"] > 0
    # SCBR actually routed everything
    assert cluster.router.stats.publications > 20


def test_wordcount_secure_matches_plain():
    c1, cl1, _ = make_cluster(6, policy=SecurityPolicy(True, True))
    r1, _ = run_wordcount(c1, cl1, LINES, 4, 2)
    c2, cl2, _ = make_cluster(6, policy=SecurityPolicy(False, False))
    r2, _ = run_wordcount(c2, cl2, LINES, 4, 2)
    assert r1 == r2


def test_kmeans_cluster_matches_device_engine():
    pts, _ = generate_points(240, 4, d=2, seed=2)
    cluster, client, _ = make_cluster(7)
    centers, hist = run_kmeans(
        cluster, client, pts, 4, n_mappers=4, n_reducers=2, max_iter=3,
        threshold=0.0,
    )
    # one reference iteration at a time (same init: first k points)
    import jax.numpy as jnp

    ref = jnp.asarray(pts[:4])
    for _ in range(len(hist)):
        ref, _ = kmeans_step_ref(jnp.asarray(pts), ref)
    np.testing.assert_allclose(centers, np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_mapper_failure_recovery():
    cluster, client, workers = make_cluster(10)
    job = MapReduceJob(
        job_id="wcf",
        map_source=__import__("repro.runtime.jobs", fromlist=["x"]).WORDCOUNT_MAP,
        reduce_source=__import__("repro.runtime.jobs", fromlist=["x"]).WORDCOUNT_REDUCE,
        data=LINES,
        n_mappers=5,
        n_reducers=3,
    )
    client.submit(job)
    # kill one hired mapper almost immediately: its unacked splits must be
    # re-executed by a standby worker hired through the same pub/sub flow
    cluster.kill_at("w0", 0.0005)
    cluster.run_until(lambda: "wcf" in client.completed)
    assert client.completed["wcf"]["pairs"]
    assert dict(client.completed["wcf"]["pairs"]) == _expected_counts(LINES)


def test_reducer_failure_recovery():
    cluster, client, workers = make_cluster(10)
    from repro.runtime.jobs import WORDCOUNT_MAP, WORDCOUNT_REDUCE

    job = MapReduceJob("wcr", WORDCOUNT_MAP, WORDCOUNT_REDUCE, LINES, 4, 3)
    client.submit(job)
    cluster.run(until=0.01)
    # a hired reducer dies mid-flight; RESHUFFLE must re-route buffered output
    reducers = [w for w in client._jobs["wcr"]["reducers"] if w]
    cluster.kill_at(reducers[0], 0.011)
    cluster.run_until(lambda: "wcr" in client.completed)
    assert dict(client.completed["wcr"]["pairs"]) == _expected_counts(LINES)


def test_straggler_backup_task():
    # w0 is 40x slower than the rest; speculative backups must complete the job
    cluster, client, workers = make_cluster(8, speeds={"w0": 1e-4})
    from repro.runtime.jobs import WORDCOUNT_MAP, WORDCOUNT_REDUCE

    job = MapReduceJob("wcs", WORDCOUNT_MAP, WORDCOUNT_REDUCE, LINES * 4, 4, 2)
    client.submit(job)
    cluster.run_until(lambda: "wcs" in client.completed)
    assert dict(client.completed["wcs"]["pairs"]) == _expected_counts(LINES * 4)
    st = client._jobs["wcs"]
    assert any(sp["backup"] for sp in st["splits"].values())


def test_rogue_worker_not_hired():
    cluster, client, workers = make_cluster(8, rogue={"w0", "w1"})
    from repro.runtime.jobs import WORDCOUNT_MAP, WORDCOUNT_REDUCE

    job = MapReduceJob("wca", WORDCOUNT_MAP, WORDCOUNT_REDUCE, LINES, 4, 2)
    client.submit(job)
    cluster.run_until(lambda: "wca" in client.completed)
    st = client._jobs["wca"]
    hired = set(st["mappers"]) | set(st["reducers"])
    assert "w0" not in hired and "w1" not in hired  # failed attestation
    assert dict(client.completed["wca"]["pairs"]) == _expected_counts(LINES)


def test_router_confidentiality():
    """The router sees only ciphertext payloads; headers stay in its enclave."""
    cluster, client, _ = make_cluster(6)
    run_wordcount(cluster, client, LINES, 4, 2)
    # all payload bytes that crossed the router were sealed: spot-check that
    # no plaintext word from the corpus appears in any stored wire blob
    # (negative control: with encryption off it WOULD appear)
    c2, cl2, _ = make_cluster(6, policy=SecurityPolicy(encryption=False, enclave=False))

    seen_plain = []
    orig_publish = c2.router.publish

    def spy(msg):
        seen_plain.append(bytes(msg.payload_ct))
        return orig_publish(msg)

    c2.router.publish = spy
    run_wordcount(c2, cl2, LINES, 4, 2)
    assert any(b"quick" in p for p in seen_plain)

    c3, cl3, _ = make_cluster(6, policy=SecurityPolicy(encryption=True, enclave=True))
    seen_ct = []
    orig3 = c3.router.publish

    def spy3(msg):
        seen_ct.append(bytes(msg.payload_ct))
        return orig3(msg)

    c3.router.publish = spy3
    run_wordcount(c3, cl3, LINES, 4, 2, job_id="wc3")
    assert not any(b"quick" in p for p in seen_ct)

"""Multi-device tests: run in subprocesses with 8 forced host devices
(smoke tests keep seeing 1 device — per the dry-run contract)."""

import pytest

from conftest import run_in_subprocess as _run


def test_secure_mapreduce_8dev():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.engine import MapReduceSpec, run_mapreduce, default_hash
    from repro.core.shuffle import SecureShuffleConfig
    from repro.crypto import chacha
    from repro.compat import make_mesh
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, 1024, dtype=np.int32))
    vals = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    def reduce_fn(k, v, valid):
        seg = jax.ops.segment_sum(jnp.where(valid, v, 0.0), jnp.where(valid, k, 0), num_segments=64)
        return jax.lax.psum(seg, "data")
    cfg = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\\x01"*12))
    spec = MapReduceSpec(map_fn=lambda k, v: (k, v), reduce_fn=reduce_fn,
                         hash_fn=default_hash, capacity=64)
    out, dropped = run_mapreduce(spec, toks, vals, mesh, secure=cfg)
    want = np.zeros(64, np.float32); np.add.at(want, np.asarray(toks), np.asarray(vals))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5)
    assert int(dropped) == 0
    print("OK")
    """)


def test_kmeans_multidev_matches_single():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.kmeans import generate_points, kmeans_step_ref, make_kmeans_step
    from repro.core.shuffle import SecureShuffleConfig
    from repro.crypto import chacha
    from repro.compat import make_mesh
    mesh = make_mesh((8,), ("data",))
    pts, _ = generate_points(1024, 8, seed=1)
    cfg = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\\x02"*12))
    step = make_kmeans_step(mesh, secure=cfg)
    c0 = jnp.asarray(pts[:8])
    c1, _ = step(jnp.asarray(pts), jnp.ones((1024,), jnp.float32), c0)
    ref, _ = kmeans_step_ref(jnp.asarray(pts), c0)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("OK")
    """)


def test_moe_shuffle_vs_dense_8dev():
    """The paper-technique dispatch equals the XLA-auto dense path."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.moe import moe_init, moe_apply
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = replace(get_config("qwen2-moe-a2.7b").reduced(), capacity_factor=8.0)
    params = moe_init(jax.random.key(0), cfg, n_model=4)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    y_shuf, aux_s, drop_s = moe_apply(cfg, params, x, mesh=mesh, dp_spec=("data",))
    cfg_d = replace(cfg, moe_dispatch="dense")
    y_dense, aux_d, drop_d = moe_apply(cfg_d, params, x)
    assert int(drop_s) == 0 and int(drop_d) == 0
    np.testing.assert_allclose(np.asarray(y_shuf), np.asarray(y_dense), rtol=2e-3, atol=2e-3)
    # aux load-balance loss: the shuffle path uses a per-seq-shard estimator
    # (GShard-style per-group), the dense path a global one — both finite,
    # not numerically identical.
    assert np.isfinite(float(aux_s)) and np.isfinite(float(aux_d))
    print("OK")
    """)


def test_secure_moe_encrypted_equals_plain_8dev():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from dataclasses import replace
    from repro.configs import get_config
    from repro.core.shuffle import SecureShuffleConfig
    from repro.crypto import chacha
    from repro.models.moe import moe_init, moe_apply
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = replace(get_config("granite-moe-3b-a800m").reduced(), capacity_factor=8.0)
    params = moe_init(jax.random.key(0), cfg, n_model=4)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    sec = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\\x03"*12))
    y_plain, _, _ = moe_apply(cfg, params, x, mesh=mesh, dp_spec=("data",))
    y_sec, _, _ = moe_apply(cfg, params, x, mesh=mesh, dp_spec=("data",), secure=sec)
    np.testing.assert_array_equal(np.asarray(y_plain), np.asarray(y_sec))
    print("OK")
    """)


def test_train_step_sharded_2x4():
    """Full train step (FSDP+TP, accumulation) on a (2,4) mesh."""
    _run("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.train.step import init_train_state, make_train_step
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("glm4-9b").reduced()
    params, opt = init_train_state(cfg, mesh, jax.random.key(0))
    # warmup=1 so the very first step has a non-zero learning rate
    step_fn, _, _ = make_train_step(cfg, mesh, accum_steps=2, donate=False, warmup=1)
    toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size, jnp.int32)
    params, opt, metrics = step_fn(params, opt, {"tokens": toks}, jnp.int32(1))
    assert np.isfinite(float(metrics["loss"]))
    params, opt, m2 = step_fn(params, opt, {"tokens": toks}, jnp.int32(2))
    assert float(m2["loss"]) < float(metrics["loss"])
    print("OK")
    """)


def test_elastic_checkpoint_8_to_4(tmp_path):
    """Save sharded on 8 devices, restore onto a 4-device mesh."""
    _run(f"""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.train.step import init_train_state
    from repro.compat import make_mesh
    mesh8 = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("rwkv6-1.6b").reduced()
    params, _ = init_train_state(cfg, mesh8, jax.random.key(0))
    mgr = CheckpointManager({str(tmp_path)!r})
    mgr.save(1, params)

    # restore onto a DIFFERENT mesh (first 4 devices)
    dev = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh4 = jax.sharding.Mesh(dev, ("data", "model"))
    from repro.parallel.sharding import logical_to_spec, rules_for_mesh
    from repro.models.lm import param_axes
    from jax.sharding import NamedSharding
    specs = logical_to_spec(param_axes(cfg), rules_for_mesh(mesh4))
    sh = jax.tree.map(lambda s: NamedSharding(mesh4, s), specs,
                      is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    restored, _ = mgr.restore(1, jax.tree.map(np.asarray, params), shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK")
    """)

"""Blocked (chunk-parallel) WKV vs exact scan recurrence."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.models.rwkv import WKV_BLOCK, _wkv_blocked, _wkv_scan


def _rand(b, t, h, c, seed=0, decay_strength=1.0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(b, t, h, c)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, c)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, t, h, c)).astype(np.float32))
    # decay in (0,1) with the production clamp |log w| <= exp(1.2)
    ww = rng.uniform(-12, 1.2, size=(b, t, h, c)) * decay_strength
    w = jnp.asarray(np.exp(-np.exp(ww)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, c)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(b, h, c, c)).astype(np.float32))
    return r, k, v, w, u, s0


@pytest.mark.parametrize("t", [16, 64, 256])
@pytest.mark.parametrize("seed", [0, 1])
def test_blocked_matches_scan(t, seed):
    r, k, v, w, u, s0 = _rand(2, t, 2, 16, seed)
    y_b, s_b = _wkv_blocked(r, k, v, w, u, s0)
    y_s, s_s = _wkv_scan(r, k, v, w, u, s0, chunk=64)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_s), rtol=2e-4, atol=2e-4)


def test_blocked_extreme_decay_no_overflow():
    """Strongest-allowed decay across a whole block stays finite."""
    b, t, h, c = 1, 64, 1, 8
    r, k, v, _, u, s0 = _rand(b, t, h, c, 3)
    w = jnp.full((b, t, h, c), float(np.exp(-np.exp(1.2))), jnp.float32)  # max decay
    y_b, s_b = _wkv_blocked(r, k, v, w, u, s0)
    assert bool(jnp.all(jnp.isfinite(y_b))) and bool(jnp.all(jnp.isfinite(s_b)))
    y_s, s_s = _wkv_scan(r, k, v, w, u, s0, chunk=64)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_s), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_hypothesis_blocked_equals_scan(seed):
    r, k, v, w, u, s0 = _rand(1, 32, 1, 8, seed)
    y_b, s_b = _wkv_blocked(r, k, v, w, u, s0)
    y_s, s_s = _wkv_scan(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_s), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_s), rtol=3e-4, atol=3e-4)


def test_gradients_flow():
    r, k, v, w, u, s0 = _rand(1, 32, 1, 8, 7)

    def loss(args):
        y, s = _wkv_blocked(*args, s0)
        return jnp.sum(y**2) + jnp.sum(s**2)

    g = jax.grad(loss)((r, k, v, w, u))
    for gi in g:
        assert bool(jnp.all(jnp.isfinite(gi)))

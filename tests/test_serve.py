"""Serving correctness: prefill + decode_step == full forward, per family."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import forward, init_params
from repro.serve.engine import decode_step, init_cache, prefill

B, TP, SMAX = 2, 16, 24


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    from dataclasses import replace

    # ample MoE capacity: token drops are seq-len dependent, which would make
    # forward(T+2) vs prefill(T) legitimately diverge on dropped tokens
    cfg = replace(get_config(arch).reduced(), capacity_factor=8.0)
    key = jax.random.key(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(jax.random.key(1), (B, TP + 2), 0, cfg.vocab_size, jnp.int32)
    frames = None
    batch = {"tokens": toks}
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
        batch["frames"] = frames

    logits_all, _ = forward(cfg, params, batch)

    cache = init_cache(cfg, B, SMAX)
    lg_prefill, cache = prefill(cfg, params, toks[:, :TP], cache, frames=frames)
    np.testing.assert_allclose(
        np.asarray(lg_prefill, np.float32),
        np.asarray(logits_all[:, TP - 1], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    lg, cache = decode_step(cfg, params, cache, toks[:, TP : TP + 1])
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_all[:, TP], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    lg2, cache = decode_step(cfg, params, cache, toks[:, TP + 1 : TP + 2])
    np.testing.assert_allclose(
        np.asarray(lg2, np.float32),
        np.asarray(logits_all[:, TP + 1], np.float32),
        rtol=2e-3, atol=2e-3,
    )

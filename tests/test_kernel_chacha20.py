"""Pallas chacha20 kernel vs pure-jnp oracle: shape/dtype sweeps.

All cases run the kernel in interpret mode, so they pass on backends without
a compiled Pallas lowering (CPU); if even the Pallas frontend or its
GPU/Triton backend module is unimportable, the module skips cleanly instead
of erroring at collection.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.crypto import chacha
from rfc_vectors import RFC_BLOCK_232

try:
    from repro.kernels.chacha20 import ops
    from repro.kernels.chacha20.kernel import chacha20_xor_blocks, chacha20_xor_row_blocks
    from repro.kernels.chacha20.ref import chacha20_xor_blocks_ref, chacha20_xor_row_blocks_ref
except ImportError as e:  # e.g. no Triton/Mosaic backend for this platform
    pytest.skip(f"Pallas chacha20 kernel unavailable: {e}", allow_module_level=True)

KW = chacha.key_to_words(bytes(range(32)))
NW = chacha.nonce_to_words(bytes.fromhex("000000000000004a00000000"))


@pytest.mark.parametrize("n_blocks,block_rows", [(8, 8), (32, 8), (64, 16), (256, 64)])
def test_kernel_matches_ref_blocks(n_blocks, block_rows):
    rng = np.random.default_rng(n_blocks)
    x = jnp.asarray(rng.integers(0, 2**32, size=(n_blocks, 16), dtype=np.uint32))
    state0 = ops.make_state0(KW, NW, 5)
    got = chacha20_xor_blocks(x, state0, block_rows=block_rows, interpret=True)
    want = chacha20_xor_blocks_ref(x, state0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_rows,n_blocks,block_rows", [(3, 8, 8), (5, 32, 16), (1, 16, 8)])
def test_rows_kernel_matches_ref(n_rows, n_blocks, block_rows):
    """Batched multi-row kernel (grid rows x tiles) vs the vmapped oracle."""
    rng = np.random.default_rng(n_rows * 100 + n_blocks)
    x = jnp.asarray(rng.integers(0, 2**32, size=(n_rows, n_blocks, 16), dtype=np.uint32))
    nonce_ids = jnp.asarray(rng.integers(0, 2**32, size=(n_rows,), dtype=np.uint32))
    ctr_starts = jnp.asarray(rng.integers(0, 2**32, size=(n_rows,), dtype=np.uint32))
    state0 = ops.make_state0(KW, NW, 0)
    got = chacha20_xor_row_blocks(x, state0, nonce_ids, ctr_starts,
                                  block_rows=block_rows, interpret=True)
    want = chacha20_xor_row_blocks_ref(x, state0, nonce_ids, ctr_starts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_rfc_vector():
    """Kernel keystream (XOR with zeros) reproduces the RFC 8439 block."""
    state0 = ops.make_state0(KW, chacha.nonce_to_words(bytes.fromhex("000000090000004a00000000")), 1)
    zeros = jnp.zeros((8, 16), jnp.uint32)
    ks = chacha20_xor_blocks(zeros, state0, block_rows=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(ks[0]), RFC_BLOCK_232)


@pytest.mark.parametrize("n_words", [1, 15, 16, 17, 128, 1000])
def test_xor_words_padding(n_words):
    rng = np.random.default_rng(n_words)
    w = jnp.asarray(rng.integers(0, 2**32, size=(n_words,), dtype=np.uint32))
    state0 = ops.make_state0(KW, NW, 0)
    got = ops.chacha20_xor_words(w, state0, impl="pallas", interpret=True)
    want = ops.chacha20_xor_words(w, state0, impl="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize(
    "shape,dtype",
    [((33,), jnp.float32), ((8, 16), jnp.bfloat16), ((129,), jnp.int32), ((5, 7), jnp.uint8)],
)
def test_ctr_crypt_array_kernel_roundtrip(shape, dtype):
    x = jax.random.normal(jax.random.key(1), shape)
    x = (x * 10).astype(dtype) if jnp.issubdtype(dtype, jnp.integer) else x.astype(dtype)
    enc = ops.ctr_crypt_array(x, KW, NW, 3, impl="pallas", interpret=True)
    # cross-check against the pure-jnp crypto path
    from repro.crypto import ctr as jctr

    enc_ref = jctr.encrypt_array(x, KW, NW, 3)
    np.testing.assert_array_equal(np.asarray(enc).view(np.uint8), np.asarray(enc_ref).view(np.uint8))
    dec = ops.ctr_crypt_array(enc, KW, NW, 3, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(x))

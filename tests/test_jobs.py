"""Tests for the canonical runtime jobs (`repro.runtime.jobs`).

Covers the paper's two reference workloads end to end through the simulated
cluster — word count (Listings 1-2) and iterated k-means (§V) — plus
`make_cluster` wiring: determinism across runs (the simulator is
virtual-time, so two identical runs must agree bit-for-bit) and result
correctness against plain-host oracles.
"""

from __future__ import annotations

from collections import Counter

import numpy as np

from repro.runtime.jobs import make_cluster, run_kmeans, run_wordcount
from repro.runtime.sim import TimingModel

LINES = [
    "the quick brown fox",
    "the lazy dog",
    "the quick dog jumps",
    "brown dog brown fox",
]


def _expected_counts(lines):
    return dict(Counter(w for line in lines for w in line.split()))


def test_make_cluster_wiring():
    cluster, client, workers = make_cluster(3)
    assert len(workers) == 3
    assert [w.name for w in workers] == ["w0", "w1", "w2"]
    # all entities registered under the one router/cluster
    for w in workers:
        assert cluster.entities[w.name] is w
    assert cluster.entities["client"] is client


def test_wordcount_correctness():
    cluster, client, _ = make_cluster(4)
    pairs, completed = run_wordcount(cluster, client, LINES,
                                     n_mappers=2, n_reducers=2)
    assert pairs == _expected_counts(LINES)
    assert completed["elapsed"] > 0.0


def test_wordcount_deterministic():
    outs = []
    for _ in range(2):
        cluster, client, _ = make_cluster(4)
        pairs, completed = run_wordcount(cluster, client, LINES,
                                         n_mappers=2, n_reducers=2)
        outs.append((pairs, completed["elapsed"], cluster.now,
                     cluster.delivered_messages))
    # virtual time: identical runs agree exactly, including timings
    assert outs[0] == outs[1]


def test_wordcount_mapper_split_invariant():
    base_cluster, base_client, _ = make_cluster(4)
    base, _ = run_wordcount(base_cluster, base_client, LINES,
                            n_mappers=1, n_reducers=1)
    for n_mappers, n_reducers in [(2, 2), (4, 3)]:
        cluster, client, _ = make_cluster(n_mappers + n_reducers)
        pairs, _ = run_wordcount(cluster, client, LINES,
                                 n_mappers=n_mappers, n_reducers=n_reducers)
        assert pairs == base


def _points(n=60, k=3, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.1, 0.9, size=(k, 2))
    pts = centers[rng.integers(0, k, size=n)] + rng.normal(scale=0.02, size=(n, 2))
    return pts.astype(np.float32)


def _kmeans_ref(points, k, max_iter, threshold):
    """Plain-host oracle for the jobs' Lua-analogue k-means math."""
    centers = np.asarray(points[:k], np.float64)
    for _ in range(max_iter):
        d2 = ((points[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d2.argmin(axis=1)
        new = centers.copy()
        for i in range(k):
            mask = assign == i
            if mask.any():
                new[i] = points[mask].mean(axis=0)
        shift = float(np.mean(np.linalg.norm(new - centers, axis=1)))
        centers = new
        if shift < threshold:
            break
    return centers.astype(np.float32)


def test_kmeans_converges_to_reference():
    pts = _points()
    cluster, client, _ = make_cluster(4)
    centers, history = run_kmeans(cluster, client, pts, 3,
                                  n_mappers=2, n_reducers=2, max_iter=20)
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    threshold = float(np.linalg.norm(hi - lo)) / 1000.0
    ref = _kmeans_ref(pts, 3, 20, threshold)
    assert history, "no iterations recorded"
    assert history[-1]["shift"] < threshold  # paper §V stop rule fired
    # same assignment-loop math as the Lua analogue, so centers land together
    assert np.allclose(np.sort(centers, axis=0), np.sort(ref, axis=0), atol=1e-3)


def test_kmeans_deterministic():
    pts = _points(seed=3)
    runs = []
    for _ in range(2):
        cluster, client, _ = make_cluster(4)
        centers, history = run_kmeans(cluster, client, pts, 3,
                                      n_mappers=2, n_reducers=2, max_iter=15)
        runs.append((centers.tobytes(), [h["shift"] for h in history],
                     [h["elapsed"] for h in history]))
    assert runs[0] == runs[1]


def test_timing_model_scales_elapsed():
    slow = TimingModel(net_bw_bytes_s=1.0e6, net_latency_s=5e-3)
    fast = TimingModel()
    elapsed = {}
    for name, timing in [("slow", slow), ("fast", fast)]:
        cluster, client, _ = make_cluster(4, timing=timing)
        _, completed = run_wordcount(cluster, client, LINES,
                                     n_mappers=2, n_reducers=2)
        elapsed[name] = completed["elapsed"]
    assert elapsed["slow"] > elapsed["fast"]

"""SecVM: oracle agreement, encrypted transport, code confidentiality."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core import secvm
from repro.crypto import chacha

KW = chacha.key_to_words(bytes(range(32)))
NW = chacha.nonce_to_words(b"\x03" * 12)


def _poly_prog():
    # r0 = 2*x^2 + 3*x + 1   (x in r1)
    return secvm.assemble(
        [
            ("LOADC", 2, 0, 0),  # r2 = 2
            ("LOADC", 3, 0, 1),  # r3 = 3
            ("LOADC", 0, 0, 2),  # r0 = 1
            ("MUL", 4, 1, 1),    # r4 = x^2
            ("FMA", 0, 4, 2),    # r0 += x^2 * 2
            ("FMA", 0, 1, 3),    # r0 += x * 3
        ],
        consts=[2.0, 3.0, 1.0],
    )


def _dist_prog():
    # r0 = sqrt((x-a)^2 + (y-b)^2), a=0.5 b=-1.5; inputs x=r1, y=r2
    return secvm.assemble(
        [
            ("LOADC", 3, 0, 0),
            ("LOADC", 4, 0, 1),
            ("SUB", 5, 1, 3),
            ("SUB", 6, 2, 4),
            ("MUL", 5, 5, 5),
            ("FMA", 5, 6, 6),
            ("SQRT", 0, 5, 0),
        ],
        consts=[0.5, -1.5],
    )


@pytest.mark.parametrize("prog_fn,n_in", [(_poly_prog, 1), (_dist_prog, 2)])
def test_vm_matches_oracle(prog_fn, n_in):
    prog = prog_fn()
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n_in, 64)).astype(np.float32)
    got = secvm.run_program(jnp.asarray(prog.code), jnp.asarray(prog.consts), jnp.asarray(x), prog.out_reg)
    want = secvm.run_oracle(prog, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_encrypted_program_roundtrip():
    prog = _poly_prog()
    code_ct, consts_ct = secvm.encrypt_program(prog, KW, NW, 7)
    # ciphertext is not the plaintext program
    assert not np.array_equal(np.asarray(code_ct), prog.code)
    x = np.linspace(-2, 2, 32, dtype=np.float32)[None]
    got = secvm.run_encrypted(code_ct, consts_ct, jnp.asarray(x), KW, NW, 7)
    np.testing.assert_allclose(np.asarray(got), 2 * x[0] ** 2 + 3 * x[0] + 1, rtol=1e-5)


def test_code_confidentiality_identical_hlo():
    """Two different programs of equal length lower to IDENTICAL HLO when the
    bytecode is an input — the platform sees the interpreter, not the code."""
    p1, p2 = _poly_prog(), _dist_prog()
    # pad p1 to p2's length with NOPs
    ln = max(p1.length, p2.length)

    def pad(p):
        code = np.zeros((ln, 4), np.int32)
        code[: p.length] = p.code
        consts = np.zeros((4,), np.float32)
        consts[: len(p.consts)] = p.consts
        return code, consts

    def run(code, consts, x):
        return secvm.run_program(code, consts, x, 0)

    x = jnp.zeros((2, 16), jnp.float32)
    texts = []
    for p in (p1, p2):
        code, consts = pad(p)
        lowered = jax.jit(run).lower(jnp.asarray(code), jnp.asarray(consts), x)
        texts.append(lowered.as_text())
    assert texts[0] == texts[1]


def test_vm_in_mapreduce_map_fn():
    """SecVM program as the map function of a secure MapReduce job."""
    from repro.core.engine import MapReduceSpec, identity_hash, run_mapreduce

    mesh = make_mesh((1,), ("data",))
    prog = _poly_prog()
    code_ct, consts_ct = secvm.encrypt_program(prog, KW, NW, 0)

    def map_fn(k, v):
        out = secvm.run_encrypted(code_ct, consts_ct, v[None, :], KW, NW, 0)
        return k, out

    def reduce_fn(k, v, valid):
        seg = jnp.where(valid, k, 0)
        return jax.lax.psum(
            jax.ops.segment_sum(jnp.where(valid, v, 0.0), seg, num_segments=4), "data"
        )

    keys = jnp.array([0, 1, 2, 3, 0, 1], jnp.int32)
    vals = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0], jnp.float32)
    out, dropped = run_mapreduce(
        MapReduceSpec(map_fn, reduce_fn, hash_fn=identity_hash, capacity=8), keys, vals, mesh
    )
    f = lambda x: 2 * x**2 + 3 * x + 1
    want = [f(1) + f(5), f(2) + f(6), f(3), f(4)]
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5)
    assert int(dropped) == 0

"""Iterative MapReduce driver: round-keystream disjointness, fused-vs-loop
bit-exactness, per-round overflow accounting, sort/grep workloads."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_subprocess as _run
from repro.compat import make_mesh
from repro.core import shuffle
from repro.core.driver import IterativeSpec, make_iterative_runner, run_iterative_mapreduce
from repro.core.engine import identity_hash
from repro.core.grep import grep_count
from repro.core.kmeans import (
    generate_points,
    kmeans_fit,
    make_kmeans_iterative_spec,
    make_kmeans_step,
)
from repro.core.shuffle import SecureShuffleConfig
from repro.crypto import chacha

def _mesh1():
    return make_mesh((1,), ("data",))


def _secure_cfg():
    return SecureShuffleConfig(
        key_words=chacha.key_to_words(bytes(range(32))),
        nonce_words=chacha.nonce_to_words(b"\x07" * 12),
        counter0=100,
    )


# --- counter-space layout ----------------------------------------------------


def test_round_keystreams_never_collide():
    """Every (round, source, row) triple draws a distinct keystream block.

    A repeated ChaCha20 block across rounds would mean a repeated
    (key, nonce, counter) input — the two-time pad the round-index nonce
    layout exists to rule out.
    """
    cfg = _secure_cfg()
    n_rows, blocks = 4, 2
    n_words = blocks * 16
    nonce_ids = jnp.arange(n_rows, dtype=jnp.uint32)  # distinct sources
    ctr_rows = jnp.arange(n_rows, dtype=jnp.uint32)   # distinct buffer rows
    seen = set()
    for rnd in range(4):
        ks = shuffle._keystream_rows(
            cfg, nonce_ids, ctr_rows, jnp.uint32(cfg.counter0), blocks, n_words,
            jnp.uint32(rnd),
        )
        for row in np.asarray(ks):
            for block in row.reshape(-1, 16):
                key = block.tobytes()
                assert key not in seen, f"keystream block reused in round {rnd}"
                seen.add(key)
    assert len(seen) == 4 * n_rows * blocks


def test_round_none_equals_round_zero():
    """Legacy single-round callers (round_index=None) keep their keystream."""
    cfg = _secure_cfg()
    ids = jnp.arange(2, dtype=jnp.uint32)
    a = shuffle._keystream_rows(cfg, ids, ids, jnp.uint32(0), 1, 16, None)
    b = shuffle._keystream_rows(cfg, ids, ids, jnp.uint32(0), 1, 16, jnp.uint32(0))
    c = shuffle._keystream_rows(cfg, ids, ids, jnp.uint32(0), 1, 16, jnp.uint32(1))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_round_offset_threads_global_round_index():
    """Chunked dispatches continue the global round index (and keystream
    space) where the previous chunk stopped, instead of restarting at 0."""

    def map_fn(state, inputs, r):
        return jnp.zeros((4,), jnp.int32), {"v": jnp.ones((4,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        return state, {"round": r}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=4, n_rounds=2)
    runner = make_iterative_runner(spec, _mesh1())
    inputs = {"x": jnp.zeros((4,), jnp.float32)}
    _, aux0, _ = runner(inputs, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(aux0["round"]), np.array([0, 1], np.uint32))
    _, aux5, _ = runner(inputs, jnp.float32(0.0), 5)
    np.testing.assert_array_equal(np.asarray(aux5["round"]), np.array([5, 6], np.uint32))


# --- fused rounds vs per-round loop ------------------------------------------


@pytest.mark.parametrize(
    "secure", [False, pytest.param(True, marks=pytest.mark.slow)]
)
def test_multiround_kmeans_bitexact_vs_loop(secure):
    """N fused driver rounds == N per-round dispatches, bit-for-bit."""
    mesh = _mesh1()
    cfg = _secure_cfg() if secure else None
    pts, _ = generate_points(256, 4, seed=5)
    pts = jnp.asarray(pts)
    w = jnp.ones((256,), jnp.float32)
    n_rounds = 3

    step = make_kmeans_step(mesh, secure=cfg)
    c_loop = jnp.asarray(pts[:4])
    loop_shifts = []
    for _ in range(n_rounds):
        c_loop, s = step(pts, w, c_loop)
        loop_shifts.append(np.asarray(s))

    spec = make_kmeans_iterative_spec(4, 1, n_rounds=n_rounds)
    final, aux, dropped = run_iterative_mapreduce(
        spec, {"p": pts, "w": w}, jnp.asarray(pts[:4]), mesh, secure=cfg
    )
    np.testing.assert_array_equal(np.asarray(final), np.asarray(c_loop))
    np.testing.assert_array_equal(np.asarray(aux["shift"]), np.asarray(loop_shifts))
    np.testing.assert_array_equal(np.asarray(dropped), np.zeros(n_rounds, np.int32))


def test_kmeans_fit_fused_matches_per_round_dispatch():
    """rounds_per_dispatch only changes dispatch count, not the answer."""
    pts, _ = generate_points(512, 5, seed=9)
    one = kmeans_fit(pts, 5, _mesh1(), max_iter=12, rounds_per_dispatch=1)
    fused = kmeans_fit(pts, 5, _mesh1(), max_iter=12, rounds_per_dispatch=4)
    assert one.n_iter == fused.n_iter
    np.testing.assert_array_equal(np.asarray(one.centers), np.asarray(fused.centers))
    assert one.center_shift == fused.center_shift
    # rounds_per_dispatch=1 degenerates to one host round-trip per iteration;
    # adaptive chunking (1, 2, 4, ...) must beat that on converged runs
    assert one.n_dispatches == one.n_iter
    assert fused.n_dispatches < one.n_dispatches


# --- per-round overflow accounting -------------------------------------------


def test_dropped_accounted_per_round():
    """Overflow is surfaced per round, not summed away."""
    n, capacity = 8, 4

    def map_fn(state, inputs, r):
        ks = jnp.arange(n, dtype=jnp.int32)
        # round 0 emits all n items (4 over capacity); later rounds emit 4
        keys = jnp.where(r == 0, ks, jnp.where(ks < capacity, ks, -1))
        return keys, {"v": jnp.ones((n,), jnp.float32)}

    def reduce_fn(state, rk, rv, valid, r):
        total = jax.lax.psum(jnp.sum(jnp.where(valid, rv["v"], 0.0)), "data")
        return state + total, {"received": total}

    spec = IterativeSpec(map_fn=map_fn, reduce_fn=reduce_fn, hash_fn=identity_hash,
                         capacity=capacity, n_rounds=2)
    # overflow is also surfaced eagerly, naming the round and capacity
    with pytest.warns(RuntimeWarning, match=r"round 0: n_dropped=4.*capacity 4"):
        final, aux, dropped = run_iterative_mapreduce(
            spec, {"x": jnp.zeros((n,), jnp.float32)}, jnp.float32(0.0), _mesh1()
        )
    np.testing.assert_array_equal(np.asarray(dropped), np.array([n - capacity, 0]))
    np.testing.assert_array_equal(np.asarray(aux["received"]),
                                  np.array([capacity, capacity], np.float32))
    assert float(final) == 2 * capacity


# --- new workloads ------------------------------------------------------------


def test_grep_streaming_rounds():
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 40, 480).astype(np.int32)
    pats = np.array([1, 7, 13, 39], np.int32)
    counts, per_round, dropped = grep_count(toks, pats, _mesh1(), n_rounds=4)
    want = np.array([(toks == p).sum() for p in pats], np.float32)
    np.testing.assert_array_equal(np.asarray(counts), want)
    # the stream is processed in chunks: per-round hits sum to the total
    np.testing.assert_array_equal(np.asarray(per_round).sum(axis=0), want)
    np.testing.assert_array_equal(np.asarray(dropped), np.zeros(4, np.int32))


def test_sampling_sort_8dev_refines_and_sorts():
    """Skewed input: uniform splitters overflow in round 0; the refined
    splitters of the last round are balanced and lossless, and concatenating
    the reducer ranges yields the sorted array (no global re-sort)."""
    _run("""
    import numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core.sort import sample_sort
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    v = (rng.exponential(scale=0.08, size=512) % 1.0).astype(np.float32)  # heavy skew
    out, counts, dropped = sample_sort(v, mesh, n_rounds=3, capacity=16, lo=0.0, hi=1.0)
    dropped = np.asarray(dropped)
    assert dropped[0] > 0, dropped   # uniform splitters overflow on this skew
    assert dropped[-1] == 0, dropped
    assert counts.sum() == 512
    np.testing.assert_array_equal(out, np.sort(v))
    # refinement balanced the reducers: within 1.5x of the fair share (64),
    # well below the structural per-reducer max of 8 sources x 16 slots = 128
    # (observed: max 77)
    assert counts.max() <= 1.5 * 512 / 8, counts
    print("OK")
    """)


@pytest.mark.slow
def test_driver_secure_equals_plain_2rounds_8dev():
    """>=2 encrypted rounds on 8 forced host devices == plaintext, exactly."""
    _run("""
    import numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core.driver import run_iterative_mapreduce
    from repro.core.kmeans import generate_points, make_kmeans_iterative_spec
    from repro.core.shuffle import SecureShuffleConfig
    from repro.crypto import chacha
    mesh = make_mesh((8,), ("data",))
    cfg = SecureShuffleConfig(key_words=chacha.key_to_words(bytes(range(32))),
                              nonce_words=chacha.nonce_to_words(b"\\x09"*12))
    pts, _ = generate_points(512, 8, seed=11)
    inputs = {"p": jnp.asarray(pts), "w": jnp.ones((512,), jnp.float32)}
    spec = make_kmeans_iterative_spec(8, 8, n_rounds=2)
    c0 = jnp.asarray(pts[:8])
    plain, aux_p, drop_p = run_iterative_mapreduce(spec, inputs, c0, mesh)
    sec, aux_s, drop_s = run_iterative_mapreduce(spec, inputs, c0, mesh, secure=cfg)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(sec))
    np.testing.assert_array_equal(np.asarray(aux_p["shift"]), np.asarray(aux_s["shift"]))
    assert int(np.asarray(drop_p).sum()) == 0 and int(np.asarray(drop_s).sum()) == 0
    print("OK")
    """)
